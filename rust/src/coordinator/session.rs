//! The [`Session`]: one wired-up training run behind the Experiment API.
//!
//! A session owns the data/model/solver/pipeline wiring for a single
//! [`TrainConfig`] and drives the Algorithm-1 step loop — per batch, a
//! fused fwd/bwd produces loss, gradients and fresh K-factor information;
//! the solver owns the EA factors + decomposition cadence (T_KU / T_KI);
//! weight updates are applied with the §5 schedules. Everything
//! *observational* (metrics CSVs, rank/pipe traces, checkpoints, spectrum
//! probes, early stopping) goes through the ordered
//! [`RunHook`](crate::coordinator::hooks::RunHook) list instead of inline
//! code, so the math in this file is exactly the old
//! `coordinator::trainer` loop — the legacy free functions are now thin
//! shims over `Session` and the golden suite pins the equivalence bitwise.
//!
//! Solvers resolve through a [`SolverRegistry`] (defaults, or the one an
//! [`ExperimentSpec`](crate::coordinator::experiment::ExperimentSpec)
//! assembled from the `[registry]` section), and the `[schedules]`
//! per-strategy sketch overrides are routed through
//! `Preconditioner::apply_strategy_schedule` at every epoch boundary.

use anyhow::{bail, Context, Result};

use crate::coordinator::config::{DataChoice, EngineChoice, ModelChoice, TrainConfig};
use crate::coordinator::hooks::{EpochCtx, HookAction, RunCtx, RunHook, StepCtx, TraceHook};
use crate::coordinator::metrics::{EpochRecord, RunResult};
use crate::data::{self, Augment, Batcher, Dataset};
use crate::linalg::{Matrix, Pcg64};
use crate::nn::loss::one_hot;
use crate::nn::{models, Network};
use crate::optim::{KfacSchedules, Preconditioner, SolverRegistry};
use crate::runtime::{CompiledModel, Engine};

/// Load (train, test) datasets per the config, normalized with train stats.
pub fn load_data(cfg: &TrainConfig) -> Result<(Dataset, Dataset)> {
    let (mut train, mut test) = match &cfg.data {
        DataChoice::Synthetic { n_train, n_test, height, width, channels } => {
            let scfg = data::SyntheticConfig {
                height: *height,
                width: *width,
                channels: *channels,
                ..Default::default()
            };
            data::generate_split(&scfg, *n_train, *n_test, cfg.seed.wrapping_add(9000))
        }
        DataChoice::Cifar { root, n_train, n_test } => {
            if !data::cifar::is_available(root) {
                bail!(
                    "CIFAR-10 binaries not found under '{root}'. Download \
                     cifar-10-binary.tar.gz and extract, or use [data] kind = \"synthetic\"."
                );
            }
            let (mut tr, mut te) = data::cifar::load_standard(root)?;
            if *n_train < tr.len() {
                let drop = tr.len() - n_train;
                tr = tr.split_tail(drop).0;
            }
            if *n_test < te.len() {
                let drop = te.len() - n_test;
                te = te.split_tail(drop).0;
            }
            (tr, te)
        }
    };
    let (mean, std) = train.normalize();
    test.apply_normalization(&mean, &std);
    Ok((train, test))
}

/// Build the schedule block for the configured run length / width.
pub fn build_schedules(cfg: &TrainConfig) -> KfacSchedules {
    let width = if cfg.sched_width > 0 {
        cfg.sched_width
    } else {
        match &cfg.model {
            ModelChoice::Mlp { widths } => widths.iter().copied().max().unwrap_or(512),
            ModelChoice::Vgg16Bn { scale_div } => (512 / scale_div).max(4),
        }
    };
    KfacSchedules::scaled(cfg.epochs.max(1), width)
}

fn build_network(cfg: &TrainConfig) -> Result<Network> {
    Ok(match &cfg.model {
        ModelChoice::Mlp { widths } => {
            if widths[0] != cfg.input_dim() {
                bail!("model input width {} != data dim {}", widths[0], cfg.input_dim());
            }
            models::mlp(widths, cfg.seed)
        }
        ModelChoice::Vgg16Bn { scale_div } => {
            if cfg.input_dim() != 3 * 32 * 32 {
                bail!("vgg16_bn needs 32x32x3 inputs; set data height/width = 32");
            }
            models::vgg16_bn(10, *scale_div, cfg.seed)
        }
    })
}

/// Attach the async factor-refresh pipeline when `[pipeline] enabled`.
/// `prop31_batch = 0` (the default) leaves the Prop. 3.1 cap disabled, as
/// documented on [`crate::pipeline::PipelineConfig`]; set it to the batch
/// size in the TOML to engage the paper's `min(r_ε·n_M, d)` mode bound.
fn attach_pipeline_if_enabled(cfg: &TrainConfig, solver: &mut dyn Preconditioner) {
    if !cfg.pipeline.enabled {
        return;
    }
    if !solver.attach_pipeline(&cfg.pipeline) {
        eprintln!(
            "[rkfac] note: solver '{}' has no decomposition cadence; [pipeline] ignored",
            solver.name()
        );
    } else if cfg.pipeline.max_stale_steps == 0 {
        eprintln!(
            "[rkfac] note: [pipeline] max_stale_steps = 0 is synchronous semantics (every \
             refresh blocks for the full round) — useful for validation, but expect no \
             speedup over the inline path"
        );
    }
}

fn augment_for(cfg: &TrainConfig) -> Augment {
    let (c, h, w) = match &cfg.data {
        DataChoice::Synthetic { height, width, channels, .. } => (*channels, *height, *width),
        DataChoice::Cifar { .. } => (3, 32, 32),
    };
    if cfg.augment {
        Augment::cifar(c, h, w)
    } else {
        Augment::none(c, h, w)
    }
}

/// Eval loop for the native engine (full batches only).
pub fn evaluate_native(net: &mut Network, test: &Dataset, batch: usize) -> (f64, f64) {
    let mut loss_sum = 0.0;
    let mut correct = 0usize;
    let mut seen = 0usize;
    let mut pos = 0;
    while pos + batch <= test.len() {
        let idx: Vec<usize> = (pos..pos + batch).collect();
        let (xb, yb) = test.gather(&idx);
        let (l, c) = net.eval_batch(&xb, &yb);
        loss_sum += l * batch as f64;
        correct += c;
        seen += batch;
        pos += batch;
    }
    if seen == 0 {
        return (f64::NAN, 0.0);
    }
    (loss_sum / seen as f64, correct as f64 / seen as f64)
}

/// Eval loop for the PJRT engine.
pub fn evaluate_pjrt(
    model: &CompiledModel,
    weights: &[Matrix],
    test: &Dataset,
    classes: usize,
) -> Result<(f64, f64)> {
    let batch = model.batch();
    let mut loss_sum = 0.0;
    let mut correct = 0usize;
    let mut seen = 0usize;
    let mut pos = 0;
    while pos + batch <= test.len() {
        let idx: Vec<usize> = (pos..pos + batch).collect();
        let (xb, yb) = test.gather(&idx);
        let y = one_hot(&yb, classes);
        let (l, c) = model.eval(weights, &xb, &y)?;
        loss_sum += l * batch as f64;
        correct += c;
        seen += batch;
        pos += batch;
    }
    if seen == 0 {
        return Ok((f64::NAN, 0.0));
    }
    Ok((loss_sum / seen as f64, correct as f64 / seen as f64))
}

/// One wired-up training run: config + solver registry + ordered hooks.
pub struct Session {
    cfg: TrainConfig,
    registry: SolverRegistry,
    hooks: Vec<Box<dyn RunHook>>,
}

impl Session {
    /// Session over [`SolverRegistry::with_defaults`], with the built-in
    /// [`TraceHook`] installed (so results carry rank/pipeline traces
    /// exactly like the legacy trainer).
    pub fn new(cfg: TrainConfig) -> Self {
        Self::with_registry(cfg, SolverRegistry::with_defaults())
    }

    /// Session over a custom registry (out-of-tree families/strategies, or
    /// the one an `ExperimentSpec` assembled from `[registry]`).
    pub fn with_registry(cfg: TrainConfig, registry: SolverRegistry) -> Self {
        Session { cfg, registry, hooks: vec![Box::new(TraceHook::new())] }
    }

    pub fn cfg(&self) -> &TrainConfig {
        &self.cfg
    }

    pub fn registry(&self) -> &SolverRegistry {
        &self.registry
    }

    /// Append a hook (fires after the built-in trace hook, in insertion
    /// order).
    pub fn add_hook(&mut self, hook: Box<dyn RunHook>) -> &mut Self {
        self.hooks.push(hook);
        self
    }

    /// Installed hooks, in firing order (diagnostics / tests).
    pub fn hook_names(&self) -> Vec<&str> {
        self.hooks.iter().map(|h| h.name()).collect()
    }

    /// Dispatch on the configured engine.
    pub fn run(&mut self) -> Result<RunResult> {
        if matches!(self.cfg.engine, EngineChoice::Native) {
            self.run_native()
        } else {
            let engine = std::sync::Arc::new(Engine::new("artifacts")?);
            self.run_pjrt(engine)
        }
    }

    /// Train with the native Rust nn engine. Returns the per-epoch record
    /// set (partial if a hook voted [`HookAction::Stop`]).
    pub fn run_native(&mut self) -> Result<RunResult> {
        let cfg = &self.cfg;
        let hooks = &mut self.hooks;
        let (train, test) = load_data(cfg)?;
        let mut net = build_network(cfg)?;
        let sched = build_schedules(cfg);
        let dims = net.kfac_dims();
        let mut solver =
            self.registry.build(&cfg.solver, sched, &dims, cfg.seed).map_err(anyhow::Error::msg)?;
        attach_pipeline_if_enabled(cfg, solver.as_mut());
        let aug = augment_for(cfg);
        let mut rng = Pcg64::with_stream(cfg.seed, 31337);
        let t0 = std::time::Instant::now();
        let mut records = Vec::new();
        for h in hooks.iter_mut() {
            h.on_run_start(&RunCtx { cfg, solver_name: solver.name() })
                .with_context(|| format!("hook '{}' failed at run start", h.name()))?;
        }
        let mut global_step = 0usize;
        'epochs: for epoch in 0..cfg.epochs {
            if !cfg.schedules.is_empty() {
                solver.apply_strategy_schedule(epoch, &cfg.schedules);
            }
            for h in hooks.iter_mut() {
                h.on_epoch_start(epoch)?;
            }
            let mut epoch_loss = 0.0;
            let mut nb = 0usize;
            for idx in Batcher::new(train.len(), cfg.batch, &mut rng) {
                let (mut xb, yb) = train.gather(&idx);
                aug.apply(&mut xb, &mut rng);
                let (loss, _) = net.train_batch(&xb, &yb, true);
                let deltas = {
                    let caps = net.kfac_captures();
                    solver.step(epoch, &caps)
                };
                let (lr, wd) = solver.lr_wd(epoch);
                net.apply_steps(&deltas, lr, wd);
                for h in hooks.iter_mut() {
                    h.on_step(&StepCtx {
                        epoch,
                        step: global_step,
                        batch_loss: loss,
                        solver: solver.as_ref(),
                    })?;
                }
                global_step += 1;
                epoch_loss += loss;
                nb += 1;
            }
            let (test_loss, test_acc) = evaluate_native(&mut net, &test, cfg.batch);
            records.push(EpochRecord {
                epoch,
                wall_s: t0.elapsed().as_secs_f64(),
                train_loss: epoch_loss / nb.max(1) as f64,
                test_loss,
                test_acc,
                decomp_s: solver.diagnostics().decomp_seconds,
            });
            let record = records.last().unwrap();
            let mut stop = false;
            for h in hooks.iter_mut() {
                let action = h.on_epoch_end(&EpochCtx {
                    epoch,
                    step: global_step,
                    record,
                    solver: solver.as_ref(),
                    net: Some(&net),
                })?;
                stop |= action == HookAction::Stop;
            }
            if stop {
                break 'epochs;
            }
        }
        let mut result = RunResult {
            solver: cfg.solver.clone(),
            seed: cfg.seed,
            records,
            total_s: t0.elapsed().as_secs_f64(),
            rank_trace: Vec::new(),
            pipe_trace: Vec::new(),
        };
        for h in hooks.iter_mut() {
            h.on_run_end(&mut result)
                .with_context(|| format!("hook '{}' failed at run end", h.name()))?;
        }
        Ok(result)
    }

    /// Train through the PJRT artifact engine (MLP configs only; the
    /// artifact's `ea_gram` Pallas kernel performs the EA blend — the
    /// solver just consumes the blended factors via `step_with_factors`).
    pub fn run_pjrt(&mut self, engine: std::sync::Arc<Engine>) -> Result<RunResult> {
        let cfg = &self.cfg;
        let hooks = &mut self.hooks;
        let artifact = match &cfg.engine {
            EngineChoice::Pjrt { config } => config.clone(),
            _ => bail!("run_pjrt called with a non-PJRT engine choice"),
        };
        let model = CompiledModel::new(engine, &artifact)
            .with_context(|| format!("loading model artifact '{artifact}'"))?;
        let (train, test) = load_data(cfg)?;
        if model.widths()[0] != train.dim() {
            bail!("artifact input width {} != data dim {}", model.widths()[0], train.dim());
        }
        if model.batch() != cfg.batch {
            bail!("artifact batch {} != configured batch {}", model.batch(), cfg.batch);
        }
        let classes = *model.widths().last().unwrap();
        let sched = build_schedules(cfg);
        let dims: Vec<(usize, usize)> =
            (0..model.n_layers()).map(|l| (model.widths()[l], model.widths()[l + 1])).collect();
        let mut solver =
            self.registry.build(&cfg.solver, sched, &dims, cfg.seed).map_err(anyhow::Error::msg)?;
        if !solver.supports_external_factors() {
            bail!(
                "PJRT path needs a solver that accepts externally-computed factors \
                 (the K-FAC engine family: kfac/rs-kfac/sre-kfac/trunc-kfac/nys-kfac); \
                 '{}' does not",
                solver.name()
            );
        }
        attach_pipeline_if_enabled(cfg, solver.as_mut());
        let mut rng = Pcg64::with_stream(cfg.seed, 31338);
        let mut weights = model.init_weights(&mut rng);
        let (mut a_f, mut g_f) = model.init_factors();
        let aug = augment_for(cfg);
        let t0 = std::time::Instant::now();
        let mut records = Vec::new();
        for h in hooks.iter_mut() {
            h.on_run_start(&RunCtx { cfg, solver_name: solver.name() })
                .with_context(|| format!("hook '{}' failed at run start", h.name()))?;
        }
        let mut global_step = 0usize;
        'epochs: for epoch in 0..cfg.epochs {
            if !cfg.schedules.is_empty() {
                solver.apply_strategy_schedule(epoch, &cfg.schedules);
            }
            for h in hooks.iter_mut() {
                h.on_epoch_start(epoch)?;
            }
            let mut epoch_loss = 0.0;
            let mut nb = 0usize;
            for idx in Batcher::new(train.len(), cfg.batch, &mut rng) {
                let (mut xb, yb) = train.gather(&idx);
                aug.apply(&mut xb, &mut rng);
                let y = one_hot(&yb, classes);
                let out = model.step(&weights, &a_f, &g_f, &xb, &y)?;
                a_f = out.a_factors;
                g_f = out.g_factors;
                let grads: Vec<&Matrix> = out.grads.iter().collect();
                let deltas = solver
                    .step_with_factors(epoch, a_f.clone(), g_f.clone(), &grads)
                    .map_err(anyhow::Error::msg)?;
                let (lr, wd) = solver.lr_wd(epoch);
                for (w, d) in weights.iter_mut().zip(deltas.iter()) {
                    for (wv, dv) in w.as_mut_slice().iter_mut().zip(d.as_slice()) {
                        *wv = *wv * (1.0 - lr * wd) + dv;
                    }
                }
                for h in hooks.iter_mut() {
                    h.on_step(&StepCtx {
                        epoch,
                        step: global_step,
                        batch_loss: out.loss,
                        solver: solver.as_ref(),
                    })?;
                }
                global_step += 1;
                epoch_loss += out.loss;
                nb += 1;
            }
            let (test_loss, test_acc) = evaluate_pjrt(&model, &weights, &test, classes)?;
            records.push(EpochRecord {
                epoch,
                wall_s: t0.elapsed().as_secs_f64(),
                train_loss: epoch_loss / nb.max(1) as f64,
                test_loss,
                test_acc,
                decomp_s: solver.diagnostics().decomp_seconds,
            });
            let record = records.last().unwrap();
            let mut stop = false;
            for h in hooks.iter_mut() {
                let action = h.on_epoch_end(&EpochCtx {
                    epoch,
                    step: global_step,
                    record,
                    solver: solver.as_ref(),
                    net: None,
                })?;
                stop |= action == HookAction::Stop;
            }
            if stop {
                break 'epochs;
            }
        }
        let mut result = RunResult {
            solver: cfg.solver.clone(),
            seed: cfg.seed,
            records,
            total_s: t0.elapsed().as_secs_f64(),
            rank_trace: Vec::new(),
            pipe_trace: Vec::new(),
        };
        for h in hooks.iter_mut() {
            h.on_run_end(&mut result)
                .with_context(|| format!("hook '{}' failed at run end", h.name()))?;
        }
        Ok(result)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::hooks::EarlyStopHook;

    fn tiny_cfg(solver: &str) -> TrainConfig {
        TrainConfig {
            solver: solver.into(),
            epochs: 3,
            batch: 32,
            seed: 1,
            model: ModelChoice::Mlp { widths: vec![108, 32, 10] },
            data: DataChoice::Synthetic {
                n_train: 320,
                n_test: 96,
                height: 6,
                width: 6,
                channels: 3,
            },
            engine: EngineChoice::Native,
            targets: vec![0.5],
            augment: false,
            out_dir: "/tmp/rkfac_session_test".into(),
            sched_width: 0,
            ..Default::default()
        }
    }

    #[test]
    fn default_session_has_trace_hook() {
        let s = Session::new(tiny_cfg("rs-kfac"));
        assert_eq!(s.hook_names(), vec!["trace"]);
    }

    #[test]
    fn early_stop_hook_truncates_run() {
        // A 0.0-accuracy target is hit at epoch 0 → exactly one record.
        let mut s = Session::new(tiny_cfg("sgd"));
        s.add_hook(Box::new(EarlyStopHook::new(0.0)));
        let r = s.run().unwrap();
        assert_eq!(r.records.len(), 1);
        // Unreachable target → full run.
        let mut s2 = Session::new(tiny_cfg("sgd"));
        s2.add_hook(Box::new(EarlyStopHook::new(2.0)));
        let r2 = s2.run().unwrap();
        assert_eq!(r2.records.len(), 3);
    }

    /// Running the same session twice must reproduce the run bitwise —
    /// the built-in trace hook restarts from round 0, it does not carry
    /// the first run's counters into the second.
    #[test]
    fn session_rerun_reproduces_traces() {
        let mut s = Session::new(tiny_cfg("rs-kfac"));
        let a = s.run().unwrap();
        let b = s.run().unwrap();
        assert_eq!(a.records.len(), b.records.len());
        for (ra, rb) in a.records.iter().zip(b.records.iter()) {
            assert_eq!(ra.train_loss, rb.train_loss);
        }
        assert_eq!(a.rank_trace.len(), b.rank_trace.len());
        assert!(!b.rank_trace.is_empty());
        assert_eq!(b.rank_trace[0].round, 0, "second run's trace restarts at round 0");
    }

    /// `[schedules]` overrides ride the session loop: the run still learns
    /// and the installed ranks follow the per-strategy schedule.
    #[test]
    fn strategy_schedules_applied_per_epoch() {
        use crate::optim::{StepSchedule, StrategySchedule};
        let mut cfg = tiny_cfg("rs-kfac");
        cfg.schedules.insert(
            "rsvd",
            StrategySchedule {
                oversample: Some(StepSchedule::new(4.0, vec![(1, 2.0)])),
                power_iter: Some(StepSchedule::constant(1.0)),
                target_rel_err: None,
            },
        );
        let r = Session::new(cfg).run().unwrap();
        assert_eq!(r.records.len(), 3);
        assert!(r.records.last().unwrap().test_loss.is_finite());
        assert!(!r.rank_trace.is_empty());
    }
}
