//! The K-FAC engine: EA Kronecker factors + pluggable decompositions.
//!
//! One implementation, any [`Decomposition`] strategy. Per Kronecker block
//! the engine maintains the EA factors Ā^(l), Γ̄^(l) (Alg. 1 lines 4/8,
//! identity-initialized), refreshes them every `T_KU` steps, recomputes
//! their (possibly randomized, truncated) eigendecompositions every `T_KI`
//! steps, and preconditions gradients with the damped low-rank inverse
//! identity of eq. (13):
//!
//! ```text
//!     s^(l) = − (Γ̄ + λI)^{-1} · Mat(g^(l)) · (Ā + λI)^{-1}
//! ```
//!
//! The strategy only controls how `Ū D̄ Ūᵀ ≈ factor` is obtained — the
//! built-ins in [`crate::rnla::decomposition`] give the paper's solvers
//! (`kfac`, `rs-kfac`, `sre-kfac`, `trunc-kfac`, `nys-kfac`); anything else
//! registered in a [`crate::rnla::DecompositionRegistry`] plugs in the same
//! way. The engine implements [`Preconditioner`], so the trainer drives it
//! (and EK-FAC, which composes over it) without knowing the concrete type.
//!
//! Decompositions can also run *off* the step loop: attach a
//! [`crate::pipeline::FactorPipeline`] via [`KfacOptimizer::attach_pipeline`]
//! and `recompute_decompositions` turns into a bounded-staleness refresh
//! against the background worker pool. The EA factors are `Arc` snapshots
//! shared with in-flight jobs (copy-on-write via [`Arc::make_mut`] in
//! [`KfacOptimizer::update_factors`] — no per-job matrix clone). Both paths
//! draw decomposition randomness from [`decomp_rng`] — one stream per
//! (round, block, side) — so the async path at zero staleness is
//! bit-identical to the inline one.

use std::sync::Arc;

use crate::linalg::{evd, gemm, Matrix, Pcg64};
use crate::nn::KfacCapture;
use crate::obs;
use crate::optim::preconditioner::{
    FactorSpectra, FactoredPolicy, PipelineDiagnostics, Preconditioner, SolverDiagnostics,
};
use crate::optim::registry::solver_display_name;
use crate::optim::schedules::{KfacSchedules, StrategySchedules};
use crate::pipeline::{FactorPipeline, OnlineMode, PipelineConfig};
use crate::rnla::{
    Decomposition, DeltaBuffer, FactorDelta, FactoredSolve, LowRankFactor, SketchConfig,
    UpdateOutcome,
};
use crate::util::codec;

/// Deterministic RNG stream for one decomposition job, shared by the inline
/// path and the pipeline workers: results depend on `(seed, round, block,
/// side)` only — never on thread scheduling — which is what lets the async
/// path at `max_stale_steps = 0` reproduce the synchronous path bitwise.
///
/// Streams are disjoint for block < 2^15 and round < 2^47 and offset away
/// from the trainer/data streams (1311, 31337, 31338).
pub fn decomp_rng(seed: u64, round: usize, block: usize, side: usize) -> Pcg64 {
    debug_assert!(block < 1 << 15, "decomp_rng: block index too large");
    debug_assert!(side < 2);
    let stream = 0x5A5A_0000_0000u64
        .wrapping_add((round as u64) << 16)
        .wrapping_add((block as u64) << 1)
        .wrapping_add(side as u64);
    Pcg64::with_stream(seed, stream)
}

/// Per-block state: EA factors + their current decompositions.
///
/// The EA factors are copy-on-write snapshots: refresh-pipeline jobs hold
/// `Arc` clones instead of deep copies, and the EA update path goes
/// through [`Arc::make_mut`] — an in-flight job keeps the buffer it
/// snapshotted while the trainer blends new statistics into a private
/// copy, and when no job is outstanding the blend mutates in place with
/// zero copies.
pub struct BlockState {
    pub a_bar: Arc<Matrix>,
    pub g_bar: Arc<Matrix>,
    pub a_dec: LowRankFactor,
    pub g_dec: LowRankFactor,
    /// Factored G-side state, for blocks the width policy routes through
    /// the Woodbury path. When set, `g_bar` stays an empty 0×0 placeholder
    /// and `g_dec` an empty factor — the o×o gram is never allocated.
    pub factored: Option<FactoredState>,
}

/// Retained-column G-side state of one factored block. The damped EA
/// recursion `Ḡ_t = ρḠ_{t-1} + (1-ρ)/n·U_tU_tᵀ` (identity-initialized) is
/// represented losslessly as `Ḡ_t = R_tR_tᵀ + γ_tI` with
/// `R_t = [√ρ·R_{t-1} | √((1-ρ)/n)·U_t]` and `γ_t = ρᵗ`; `R_t` is trimmed
/// to the policy's `max_cols` window (oldest — most ρ-discounted —
/// columns first), so memory is O(o·max_cols) instead of O(o²).
pub struct FactoredState {
    /// `R_t` — retained EA-scaled gradient columns (o × k, k ≤ max_cols).
    pub retained: Matrix,
    /// `γ_t` — the EA-decayed identity coefficient (starts at 1).
    pub gamma: f64,
    /// The installed factored solve (rebuilt on the T_KI cadence from the
    /// then-current `retained`/`gamma`, like `g_dec` on the dense path).
    pub solve: FactoredSolve,
}

/// The K-FAC engine over a pluggable decomposition strategy.
pub struct KfacOptimizer {
    strategy: Arc<dyn Decomposition>,
    /// Display name (`kfac`/`rs-kfac`/… for built-in strategies).
    name: String,
    pub sched: KfacSchedules,
    pub blocks: Vec<BlockState>,
    /// Steps taken (drives T_KU / T_KI phases).
    pub step_count: usize,
    decomp_fresh: bool,
    /// Base seed for the per-(round, block, side) decomposition streams.
    seed: u64,
    /// Background refresh service; `None` = inline (synchronous) refresh.
    pipeline: Option<FactorPipeline>,
    /// Sketch parameters installed for the current epoch by a `[schedules]`
    /// per-strategy override (routed through [`Decomposition::tune`]);
    /// `None` = derive from the §5 schedule block as always.
    sketch_override: Option<SketchConfig>,
    /// Width policy routing blocks to factored G-side solves. The default
    /// (`Off`) leaves the engine bitwise the legacy eigen path.
    policy: FactoredPolicy,
    /// Column-factoring strategy backing the factored blocks' G-side
    /// (`None` when the policy routes nothing).
    core: Option<Arc<dyn Decomposition>>,
    /// Wall-time the *step loop* spends on decompositions (the paper's
    /// headline cost). With a pipeline attached this is only the blocked
    /// portion of each refresh — the overlap win shows up here.
    pub decomp_seconds: f64,
    pub n_decomps: usize,
    /// Online incremental-update mode (`[pipeline] online`). `Off` keeps
    /// the engine bitwise the recompute-from-scratch path.
    online: OnlineMode,
    /// Refresh rounds between mandatory full decompositions when online is
    /// active (round 0 and every `correction_every`-th round recompute).
    correction_every: usize,
    /// Per-(block, side) EA deltas accumulated since the last consumed
    /// refresh; `Some` only while online updates are active for this
    /// engine's strategy.
    deltas: Option<DeltaBuffer>,
    /// Inline refreshes served by the incremental update path.
    n_online_updates: usize,
    /// Inline refreshes that ran a full decomposition.
    n_full_decomps: usize,
}

impl KfacOptimizer {
    /// `dims[l] = (d_A, d_G)` per Kronecker block (from `Network::kfac_dims`
    /// or the artifact widths). Factors start at identity (Alg. 1).
    pub fn new(
        strategy: Arc<dyn Decomposition>,
        sched: KfacSchedules,
        dims: &[(usize, usize)],
        seed: u64,
    ) -> Self {
        Self::with_policy(strategy, None, sched, dims, seed, FactoredPolicy::default())
            .expect("an Off factored policy cannot fail construction")
    }

    /// Construct with a factored width policy: blocks whose G-side width
    /// the policy routes get retained-column Woodbury state instead of a
    /// dense o×o `Γ̄` — the gram is never allocated for them. `core`
    /// overrides the column-factoring strategy; when `None`, a
    /// column-factoring `strategy` (e.g. `woodbury`) serves as its own
    /// core. Errs if the policy routes a block but no column-factoring
    /// core is available. A policy that routes nothing yields an engine
    /// bitwise-identical to [`KfacOptimizer::new`].
    pub fn with_policy(
        strategy: Arc<dyn Decomposition>,
        core: Option<Arc<dyn Decomposition>>,
        sched: KfacSchedules,
        dims: &[(usize, usize)],
        seed: u64,
        mut policy: FactoredPolicy,
    ) -> Result<Self, String> {
        let core = core.or_else(|| {
            if strategy.factors_columns() {
                // A column-factoring strategy spec (`kfac+woodbury`) is its
                // own core; with no explicit mode it means "all blocks".
                if policy.mode == crate::optim::preconditioner::FactoredMode::Off {
                    policy.mode = crate::optim::preconditioner::FactoredMode::All;
                }
                Some(Arc::clone(&strategy))
            } else {
                None
            }
        });
        let lambda0 = sched.lambda.at(0);
        let blocks = dims
            .iter()
            .map(|&(da, dg)| {
                let factored = if policy.routes_to_factored(dg) {
                    let core = core.as_ref().ok_or_else(|| {
                        format!(
                            "factored policy routes a {dg}-wide block but strategy '{}' has no \
                             column-factored path and no factored core is configured",
                            strategy.key()
                        )
                    })?;
                    if !core.factors_columns() {
                        return Err(format!(
                            "factored core '{}' does not consume gradient columns",
                            core.key()
                        ));
                    }
                    obs::counter_add("kfac.factored_g_block", 1);
                    // Ḡ_0 = I exactly: no retained columns, γ = 1.
                    let solve = FactoredSolve::build(Matrix::zeros(dg, 0), 1.0, lambda0)?;
                    Some(FactoredState { retained: Matrix::zeros(dg, 0), gamma: 1.0, solve })
                } else {
                    None
                };
                let dg_dense = if factored.is_some() { 0 } else { dg };
                if factored.is_none() {
                    obs::counter_add("kfac.dense_g_alloc", 1);
                }
                Ok(BlockState {
                    a_bar: Arc::new(Matrix::eye(da)),
                    g_bar: Arc::new(Matrix::eye(dg_dense)),
                    a_dec: LowRankFactor::new(Matrix::eye(da), vec![1.0; da]),
                    g_dec: if factored.is_some() {
                        LowRankFactor::empty(dg)
                    } else {
                        LowRankFactor::new(Matrix::eye(dg), vec![1.0; dg])
                    },
                    factored,
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        let name = solver_display_name("kfac", strategy.key());
        Ok(KfacOptimizer {
            strategy,
            name,
            sched,
            blocks,
            step_count: 0,
            decomp_fresh: true,
            seed,
            pipeline: None,
            sketch_override: None,
            policy,
            core,
            decomp_seconds: 0.0,
            n_decomps: 0,
            online: OnlineMode::Off,
            correction_every: 16,
            deltas: None,
            n_online_updates: 0,
            n_full_decomps: 0,
        })
    }

    /// Switch decomposition refreshes to incremental basis maintenance
    /// (`[pipeline] online`): EA updates are captured as low-rank
    /// [`FactorDelta`]s and refreshes rotate the previous eigenbasis
    /// instead of recomputing it, with a full decomposition every
    /// `correction_every` rounds. Works in both the inline and the
    /// pipelined refresh path. Returns `false` — leaving the engine on the
    /// recompute path — when the mode or strategy has no update support.
    pub fn set_online(&mut self, mode: OnlineMode, correction_every: usize) -> bool {
        self.online = mode;
        self.correction_every = correction_every.max(1);
        let active = mode != OnlineMode::Off
            && mode.allows(self.strategy.key())
            && self.strategy.supports_update();
        self.deltas = if active { Some(DeltaBuffer::new(self.blocks.len())) } else { None };
        active
    }

    /// Whether refreshes may take the incremental update path.
    fn online_active(&self) -> bool {
        self.deltas.is_some()
    }

    /// Refreshes served by the incremental update path (inline plus, with
    /// a pipeline attached, update jobs shipped to the workers).
    pub fn online_updates(&self) -> usize {
        self.n_online_updates + self.pipeline.as_ref().map_or(0, |p| p.update_jobs())
    }

    /// Refreshes that ran a full decomposition — the count online mode
    /// exists to shrink. Inline plus pipelined full jobs.
    pub fn full_decomps(&self) -> usize {
        self.n_full_decomps
            + self
                .pipeline
                .as_ref()
                .map_or(0, |p| p.jobs_completed().saturating_sub(p.update_jobs()))
    }

    /// Whether any block's G-side runs through the factored (Woodbury)
    /// path — such engines refuse the pipeline, external dense factors,
    /// and dense G spectra.
    pub fn has_factored_blocks(&self) -> bool {
        self.blocks.iter().any(|b| b.factored.is_some())
    }

    /// The decomposition strategy backing the damped inverse applications.
    pub fn strategy(&self) -> &Arc<dyn Decomposition> {
        &self.strategy
    }

    /// Route decomposition refreshes through a background
    /// [`FactorPipeline`] (double-buffered slots, bounded staleness,
    /// optional per-layer adaptive rank). Replaces any previous pipeline.
    /// Returns `false` — and attaches nothing — when any block is
    /// factored: retained-U jobs are inline-only (they do not ship over
    /// the factor wire format), and the config layer rejects the combination
    /// up front with a layer-citing error.
    pub fn attach_pipeline(&mut self, cfg: PipelineConfig) -> bool {
        if self.has_factored_blocks() {
            return false;
        }
        let dims: Vec<(usize, usize)> =
            self.blocks.iter().map(|b| (b.a_bar.rows(), b.g_bar.rows())).collect();
        let init_rank = self.sched.rank.at(0).max(1.0) as usize;
        self.pipeline = Some(FactorPipeline::new(cfg, &dims, init_rank, self.sched.rho));
        true
    }

    /// The attached refresh pipeline, if any (stats / contract probes).
    pub fn pipeline(&self) -> Option<&FactorPipeline> {
        self.pipeline.as_ref()
    }

    /// Install this epoch's `[schedules]` per-strategy sketch override
    /// (resolved through the strategy's `tune` hook). With no entry for
    /// this engine's strategy the override is cleared, so subsequent
    /// refreshes fall back to the §5 schedule — bitwise-identical to the
    /// pre-override behaviour.
    pub fn apply_strategy_schedule(&mut self, epoch: usize, set: &StrategySchedules) -> bool {
        self.sketch_override = set.sketch_for(self.strategy.as_ref(), &self.sched, epoch);
        self.sketch_override.is_some()
    }

    /// Current decomposition rank per block: `(rank_A, rank_Γ)`. For
    /// factored blocks the Γ rank is the installed solve's retained-column
    /// count (the T×T core dimension).
    pub fn current_ranks(&self) -> Vec<(usize, usize)> {
        self.blocks
            .iter()
            .map(|b| {
                let rg = match &b.factored {
                    Some(f) => f.solve.rank(),
                    None => b.g_dec.rank(),
                };
                (b.a_dec.rank(), rg)
            })
            .collect()
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Whether this step refreshes the EA factors (T_KU boundary).
    pub fn is_factor_update_step(&self) -> bool {
        self.step_count % self.sched.t_ku == 0
    }

    fn is_inverse_step(&self, epoch: usize) -> bool {
        let t_ki = self.sched.t_ki.at(epoch).max(1.0) as usize;
        self.step_count % t_ki == 0
    }

    /// Update the EA factors from fresh captures (native-engine path).
    /// Copy-on-write against in-flight pipeline jobs: `Arc::make_mut`
    /// clones the factor only when a job still holds the old snapshot.
    pub fn update_factors(&mut self, caps: &[KfacCapture<'_>]) {
        assert_eq!(caps.len(), self.blocks.len(), "update_factors: block count");
        let rho = self.sched.rho;
        for (bi, (b, c)) in self.blocks.iter_mut().zip(caps.iter()).enumerate() {
            let n = c.a.cols() as f64;
            gemm::ea_gram_update(Arc::make_mut(&mut b.a_bar), rho, c.a, n);
            // Online mode shadows every EA gram update with a low-rank
            // capture, so the next refresh can rotate the installed basis
            // instead of re-decomposing the dense factor. Factored G-side
            // blocks keep their own retained-column state and never take
            // deltas.
            if let Some(buf) = self.deltas.as_mut() {
                buf.absorb(bi, crate::pipeline::SIDE_A, FactorDelta::from_capture(c.a, rho, n));
            }
            let ng = c.g.cols() as f64;
            match b.factored.as_mut() {
                // Factored blocks retain the EA-scaled gradient columns
                // instead of blending an o×o gram: the same recursion,
                // represented as R_t = [√ρ·R_{t-1} | √((1-ρ)/n)·U_t] with
                // γ_t = ρ·γ_{t-1} — exact while the window never trims.
                Some(f) => {
                    f.gamma *= rho;
                    let fresh = c.g * ((1.0 - rho) / ng).sqrt();
                    let mut retained = (&f.retained * rho.sqrt()).hcat(&fresh);
                    let cols = retained.cols();
                    if cols > self.policy.max_cols {
                        retained =
                            retained.slice(0, retained.rows(), cols - self.policy.max_cols, cols);
                    }
                    f.retained = retained;
                }
                None => {
                    gemm::ea_gram_update(Arc::make_mut(&mut b.g_bar), rho, c.g, ng);
                    if let Some(buf) = self.deltas.as_mut() {
                        buf.absorb(
                            bi,
                            crate::pipeline::SIDE_G,
                            FactorDelta::from_capture(c.g, rho, ng),
                        );
                    }
                }
            }
        }
        self.decomp_fresh = false;
    }

    /// Inject externally-computed EA factors (PJRT artifact path — the
    /// `ea_gram` Pallas kernel already blended them). Any snapshot an
    /// in-flight job holds simply keeps the previous allocation.
    pub fn set_factors(&mut self, a: Vec<Matrix>, g: Vec<Matrix>) {
        assert_eq!(a.len(), self.blocks.len());
        debug_assert!(
            !self.has_factored_blocks(),
            "set_factors delivers dense o×o grams; factored blocks never materialize one"
        );
        for ((b, a_new), g_new) in self.blocks.iter_mut().zip(a).zip(g) {
            b.a_bar = Arc::new(a_new);
            b.g_bar = Arc::new(g_new);
        }
        self.decomp_fresh = false;
    }

    /// Recompute decompositions of all blocks (Alg. 4/5 lines 3-4; Alg. 1
    /// line 12 for the exact strategy). With a pipeline attached this is a
    /// bounded-staleness refresh against the background workers instead of
    /// an inline recomputation.
    pub fn recompute_decompositions(&mut self, epoch: usize) {
        let cfg = match &self.sketch_override {
            Some(o) => o.clone(),
            None => SketchConfig::new(
                self.sched.rank.at(epoch).max(1.0) as usize,
                self.sched.oversample.at(epoch).max(0.0) as usize,
                self.sched.n_power_iter,
            ),
        };
        let round = self.n_decomps;
        let strategy = Arc::clone(&self.strategy);
        let _sp = obs::span("kfac.refresh")
            .arg("round", round)
            .arg("strategy", strategy.key())
            .arg("pipelined", self.pipeline.is_some());
        let sw = obs::clock::Stopwatch::start();
        if let Some(p) = self.pipeline.as_mut() {
            debug_assert!(
                self.blocks.iter().all(|b| b.factored.is_none()),
                "factored blocks are inline-only; attach_pipeline refuses them"
            );
            p.refresh_with_deltas(
                &mut self.blocks,
                &strategy,
                &cfg,
                self.seed,
                round,
                self.step_count as u64,
                self.deltas.as_mut(),
            );
        } else {
            let span_name = format!("kfac.refresh.{}", strategy.key());
            let lambda = self.sched.lambda.at(epoch);
            // Online refresh: rotate the installed basis with the EA deltas
            // accumulated since the last round, except on periodic
            // correction rounds (which include round 0) where the dense
            // snapshot is re-decomposed from scratch.
            let online = self.online_active() && round % self.correction_every.max(1) != 0;
            for (bi, b) in self.blocks.iter_mut().enumerate() {
                for side in [crate::pipeline::SIDE_A, crate::pipeline::SIDE_G] {
                    if side == crate::pipeline::SIDE_G {
                        if let Some(f) = b.factored.as_mut() {
                            // Factored G-side: rebuild the Woodbury solve
                            // from the retained columns — O(o·k² + k³),
                            // never touching an o×o buffer. Same RNG
                            // stream discipline as the dense path (the
                            // sketched core draws its row sample here).
                            let core = self
                                .core
                                .as_ref()
                                .expect("factored block without a core strategy");
                            let _job = obs::span(&span_name)
                                .arg("block", bi)
                                .arg("side", side)
                                .arg("strategy", core.key())
                                .arg("rank", f.retained.cols())
                                .arg("factored", true);
                            let mut rng = decomp_rng(self.seed, round, bi, side);
                            f.solve = core
                                .factor_columns(
                                    &f.retained,
                                    f.gamma,
                                    lambda,
                                    self.policy.col_sample,
                                    &mut rng,
                                )
                                .unwrap_or_else(|e| {
                                    panic!("factored refresh failed (block {bi}): {e}")
                                });
                            continue;
                        }
                    }
                    let (dim, matrix) = if side == crate::pipeline::SIDE_A {
                        (b.a_bar.rows(), &b.a_bar)
                    } else {
                        (b.g_bar.rows(), &b.g_bar)
                    };
                    // Take this side's accumulated delta; outside an online
                    // round it is discarded — the dense snapshot subsumes
                    // it, and composing it into the *next* basis would
                    // double-count the captures.
                    let delta = match self.deltas.as_mut().and_then(|buf| buf.take(bi, side)) {
                        Some(d) if online => Some(d),
                        _ => None,
                    };
                    let prev_rank = if side == crate::pipeline::SIDE_A {
                        b.a_dec.rank()
                    } else {
                        b.g_dec.rank()
                    };
                    let attempt = delta.is_some() && prev_rank > 0;
                    let flops_pred = match (&delta, attempt) {
                        (Some(d), true) => strategy
                            .update_meta(dim, d.n_cols(), &cfg)
                            .map(|m| m.flops)
                            .unwrap_or_else(|| strategy.meta(dim, &cfg).flops),
                        _ => strategy.meta(dim, &cfg).flops,
                    };
                    let _job = obs::span(&span_name)
                        .arg("block", bi)
                        .arg("side", side)
                        .arg("strategy", strategy.key())
                        .arg("rank", cfg.rank)
                        .arg("flops_pred", flops_pred)
                        .arg("op", if attempt { "update" } else { "decompose" });
                    let mut rng = decomp_rng(self.seed, round, bi, side);
                    // An update attempt that the strategy declines falls
                    // back to a fresh decomposition on the *same* RNG
                    // stream — the eigenbasis update never draws, so the
                    // fallback is bitwise what a plain recompute produces.
                    let mut updated = None;
                    if attempt {
                        let prev = if side == crate::pipeline::SIDE_A { &b.a_dec } else { &b.g_dec };
                        if let UpdateOutcome::Updated(f) =
                            strategy.update(prev, delta.as_ref().unwrap(), &cfg, &mut rng)
                        {
                            updated = Some(f);
                        }
                    }
                    let dec = match updated {
                        Some(f) => {
                            self.n_online_updates += 1;
                            obs::counter_add("kfac.refresh.update", 1);
                            f
                        }
                        None => {
                            self.n_full_decomps += 1;
                            obs::counter_add("kfac.refresh.full", 1);
                            strategy.decompose(matrix, &cfg, &mut rng)
                        }
                    };
                    if side == crate::pipeline::SIDE_A {
                        b.a_dec = dec;
                    } else {
                        b.g_dec = dec;
                    }
                }
            }
        }
        self.decomp_seconds += sw.elapsed_s();
        self.n_decomps += 1;
        self.decomp_fresh = true;
    }

    /// Refresh the decompositions when the T_KI cadence (or the mandatory
    /// first-step recomputation after a factor update) makes them due.
    fn refresh_if_due(&mut self, epoch: usize) {
        if self.is_inverse_step(epoch) || !self.decomp_fresh && self.step_count == 0 {
            self.recompute_decompositions(epoch);
        }
    }

    /// Precondition gradients into weight deltas `-α·(Γ̄+λ)⁻¹ g (Ā+λ)⁻¹`
    /// (weight decay is applied by `Network::apply_steps`). Takes `&mut`
    /// for the factored blocks' lazy core-refactorization when λ moved
    /// since the last T_KI refresh — an O(k³) rebuild, no dense work.
    pub fn precondition(&mut self, grads: &[&Matrix], epoch: usize) -> Vec<Matrix> {
        let lambda = self.sched.lambda.at(epoch);
        let alpha = self.sched.alpha.at(epoch);
        assert_eq!(grads.len(), self.blocks.len(), "precondition: block count");
        grads
            .iter()
            .zip(self.blocks.iter_mut())
            .map(|(g, b)| {
                let left = match b.factored.as_mut() {
                    Some(f) => f.solve.apply(lambda, g),
                    None => b.g_dec.damped_inverse_apply(lambda, g),
                };
                let mut s = b.a_dec.damped_inverse_apply_right(lambda, &left);
                s.scale_inplace(-alpha);
                s
            })
            .collect()
    }

    /// Full native-engine step: refresh factors (T_KU), refresh decomps
    /// (T_KI), precondition. Returns per-block weight deltas. Delegates to
    /// the [`Preconditioner::step`] phase composition — there is exactly
    /// one step sequence, whichever entry point is used.
    pub fn step(&mut self, epoch: usize, caps: &[KfacCapture<'_>]) -> Vec<Matrix> {
        Preconditioner::step(self, epoch, caps)
    }

    /// Runtime-path step: EA factors were already blended by the artifact.
    pub fn step_with_factors(
        &mut self,
        epoch: usize,
        a: Vec<Matrix>,
        g: Vec<Matrix>,
        grads: &[&Matrix],
    ) -> Vec<Matrix> {
        if self.is_factor_update_step() {
            self.set_factors(a, g);
        }
        if self.is_inverse_step(epoch) {
            self.recompute_decompositions(epoch);
        }
        let deltas = self.precondition(grads, epoch);
        self.step_count += 1;
        deltas
    }

    /// Serialize the engine's full resumable state: per-block EA factors
    /// and installed decompositions, the step / refresh-round counters
    /// (`n_decomps` positions the per-(round, block, side) decomposition
    /// RNG streams — restoring it restores the streams), and — when a
    /// pipeline is attached — the slot versions and controller ranks. The
    /// strategy key is embedded so a checkpoint cannot silently restore
    /// into a differently-configured engine.
    pub fn save_state_bytes(&self) -> Vec<u8> {
        let mut w = codec::ByteWriter::new();
        // A factored engine writes the v2 layout (per-block kind byte +
        // retained-column state); without factored blocks the bytes are
        // the legacy KF01 layout verbatim, so dense checkpoints stay
        // bitwise-stable with the subsystem compiled in but off.
        let v2 = self.has_factored_blocks();
        w.tag(if v2 { b"KF02" } else { b"KF01" });
        w.str(self.strategy.key());
        w.u64(self.step_count as u64);
        w.u64(self.n_decomps as u64);
        w.u8(self.decomp_fresh as u8);
        w.f64(self.decomp_seconds);
        w.u64(self.blocks.len() as u64);
        for b in &self.blocks {
            if v2 {
                w.u8(b.factored.is_some() as u8);
            }
            match &b.factored {
                Some(f) => {
                    w.matrix(&b.a_bar);
                    w.matrix(&b.a_dec.u);
                    w.f64s(&b.a_dec.d);
                    w.matrix(&f.retained);
                    w.f64(f.gamma);
                    w.matrix(f.solve.u());
                    w.matrix(f.solve.gram());
                    w.f64(f.solve.gamma());
                    w.f64(f.solve.lambda());
                }
                None => {
                    w.matrix(&b.a_bar);
                    w.matrix(&b.g_bar);
                    w.matrix(&b.a_dec.u);
                    w.f64s(&b.a_dec.d);
                    w.matrix(&b.g_dec.u);
                    w.f64s(&b.g_dec.d);
                }
            }
        }
        match &self.pipeline {
            Some(p) => {
                w.u8(1);
                let mut pw = codec::ByteWriter::new();
                p.save_state(&mut pw);
                w.blob(&pw.into_bytes());
            }
            None => w.u8(0),
        }
        // Online incremental-basis state: the pending (composed) EA deltas
        // and the update-vs-full counters. Written only when online mode is
        // active, so online-off checkpoints stay byte-identical to the
        // pre-online layout — and pre-online checkpoints simply end above
        // (the reader tolerates the missing trailing section).
        if let Some(buf) = &self.deltas {
            w.u8(1);
            w.u64(self.n_online_updates as u64);
            w.u64(self.n_full_decomps as u64);
            w.u64(buf.slot_count() as u64);
            for slot in 0..buf.slot_count() {
                match buf.peek(slot / 2, slot % 2) {
                    Some(d) => {
                        w.u8(1);
                        w.matrix(&d.cols);
                        w.f64(d.rho);
                    }
                    None => w.u8(0),
                }
            }
        }
        w.into_bytes()
    }

    /// Restore [`KfacOptimizer::save_state_bytes`] output into a freshly
    /// built engine of the same strategy and block dimensions. Continuing
    /// the step loop afterwards reproduces the uninterrupted run bitwise
    /// (inline, or pipelined at `max_stale_steps = 0`).
    pub fn load_state_bytes(&mut self, bytes: &[u8]) -> Result<(), String> {
        let mut r = codec::ByteReader::new(bytes);
        // Accept both layouts: KF01 (dense-only legacy) and KF02 (per-block
        // kind byte, factored blocks carry retained-column state).
        let v2 = {
            let mut probe = codec::ByteReader::new(bytes);
            probe.tag(b"KF02").is_ok()
        };
        if v2 {
            r.tag(b"KF02")?;
        } else {
            r.tag(b"KF01")?;
        }
        let key = r.str()?;
        if key != self.strategy.key() {
            return Err(format!(
                "checkpoint was written by decomposition strategy '{key}', this run uses '{}'",
                self.strategy.key()
            ));
        }
        self.step_count = r.u64()? as usize;
        self.n_decomps = r.u64()? as usize;
        self.decomp_fresh = r.u8()? != 0;
        self.decomp_seconds = r.f64()?;
        let nb = r.u64()? as usize;
        if nb != self.blocks.len() {
            return Err(format!(
                "checkpoint has {nb} Kronecker blocks, this model has {}",
                self.blocks.len()
            ));
        }
        for (bi, b) in self.blocks.iter_mut().enumerate() {
            let kind = if v2 { r.u8()? } else { 0 };
            if (kind == 1) != b.factored.is_some() {
                return Err(format!(
                    "block {bi}: checkpoint {} factored G-side state but this engine's width \
                     policy {} it",
                    if kind == 1 { "carries" } else { "has no" },
                    if b.factored.is_some() { "expects" } else { "does not use" }
                ));
            }
            let a_bar = r.matrix()?;
            if a_bar.shape() != (b.a_bar.rows(), b.a_bar.cols()) {
                return Err(format!("block {bi}: checkpointed Ā shape mismatch"));
            }
            if kind == 1 {
                let a_u = r.matrix()?;
                let a_d = r.f64s()?;
                if a_u.cols() != a_d.len() || a_u.rows() != a_bar.rows() {
                    return Err(format!("block {bi}: checkpointed Ā decomposition is inconsistent"));
                }
                let dg = b.factored.as_ref().map(|f| f.retained.rows()).expect("kind checked");
                let retained = r.matrix()?;
                let gamma = r.f64()?;
                let s_u = r.matrix()?;
                let s_gram = r.matrix()?;
                let s_gamma = r.f64()?;
                let s_lambda = r.f64()?;
                if retained.rows() != dg || s_u.rows() != dg {
                    return Err(format!(
                        "block {bi}: checkpointed factored G-side state is for width {}, this \
                         block is {dg}-wide",
                        retained.rows()
                    ));
                }
                // The Cholesky refactorization is deterministic in the
                // serialized (gram, γ, λ), so the restored solve continues
                // bitwise.
                let solve = FactoredSolve::from_parts(s_u, s_gram, s_gamma, s_lambda)
                    .map_err(|e| format!("block {bi}: factored solve restore: {e}"))?;
                b.a_bar = Arc::new(a_bar);
                b.a_dec = LowRankFactor::new(a_u, a_d);
                let f = b.factored.as_mut().expect("kind checked above");
                f.retained = retained;
                f.gamma = gamma;
                f.solve = solve;
            } else {
                let g_bar = r.matrix()?;
                if g_bar.shape() != (b.g_bar.rows(), b.g_bar.cols()) {
                    return Err(format!("block {bi}: checkpointed Γ̄ shape mismatch"));
                }
                let a_u = r.matrix()?;
                let a_d = r.f64s()?;
                let g_u = r.matrix()?;
                let g_d = r.f64s()?;
                if a_u.cols() != a_d.len() || a_u.rows() != a_bar.rows() {
                    return Err(format!("block {bi}: checkpointed Ā decomposition is inconsistent"));
                }
                if g_u.cols() != g_d.len() || g_u.rows() != g_bar.rows() {
                    return Err(format!("block {bi}: checkpointed Γ̄ decomposition is inconsistent"));
                }
                b.a_bar = Arc::new(a_bar);
                b.g_bar = Arc::new(g_bar);
                b.a_dec = LowRankFactor::new(a_u, a_d);
                b.g_dec = LowRankFactor::new(g_u, g_d);
            }
        }
        let has_pipeline_state = r.u8()? != 0;
        if has_pipeline_state {
            // Checkpointed with a pipeline. Resumed without one, the slot
            // snapshot is simply not needed (values at stale = 0 are
            // pipeline-invariant) — the blob is read and dropped.
            let blob = r.blob()?;
            if let Some(p) = self.pipeline.as_mut() {
                let mut pr = codec::ByteReader::new(blob);
                p.load_state(&mut pr, &self.blocks)?;
                pr.finish()?;
            }
        }
        // Online incremental-basis state — a trailing optional section:
        // pre-online and online-off checkpoints end right here.
        let has_online = match r.u8() {
            Ok(v) => v != 0,
            Err(_) => false,
        };
        if has_online {
            self.n_online_updates = r.u64()? as usize;
            self.n_full_decomps = r.u64()? as usize;
            let slots = r.u64()? as usize;
            let mut restored = DeltaBuffer::new((slots + 1) / 2);
            for slot in 0..slots {
                if r.u8()? != 0 {
                    let cols = r.matrix()?;
                    let rho = r.f64()?;
                    restored.absorb(slot / 2, slot % 2, FactorDelta::new(cols, rho));
                }
            }
            // Restore the pending deltas only when this engine runs online
            // too; otherwise the section is read and dropped — the next
            // full refresh subsumes whatever the deltas described.
            if self.deltas.is_some() {
                if slots != 2 * self.blocks.len() {
                    return Err(format!(
                        "checkpoint online state has {slots} delta slots, this model needs {}",
                        2 * self.blocks.len()
                    ));
                }
                self.deltas = Some(restored);
            }
        }
        r.finish()
    }

    /// Current eigen-spectrum (descending) of each block's Ā — the Fig. 1
    /// probe. Exact EVD (diagnostics only, not the training hot path);
    /// batched so the threaded backend fans the per-block decompositions
    /// out across workers (bitwise-identical to the sequential map).
    pub fn a_spectra(&self) -> Vec<Vec<f64>> {
        let mats: Vec<&Matrix> = self.blocks.iter().map(|b| b.a_bar.as_ref()).collect();
        evd::sym_evd_batch(&mats).into_iter().map(|e| e.lambda).collect()
    }

    /// Like [`KfacOptimizer::a_spectra`], for Γ̄ — factored blocks yield an
    /// empty spectrum (their o×o gram exists only implicitly; an exact EVD
    /// probe would require materializing exactly what the factored path
    /// avoids).
    pub fn g_spectra(&self) -> Vec<Vec<f64>> {
        let dense: Vec<&Matrix> = self
            .blocks
            .iter()
            .filter(|b| b.factored.is_none())
            .map(|b| b.g_bar.as_ref())
            .collect();
        let mut spectra = evd::sym_evd_batch(&dense).into_iter().map(|e| e.lambda);
        self.blocks
            .iter()
            .map(|b| {
                if b.factored.is_some() {
                    Vec::new()
                } else {
                    spectra.next().expect("one spectrum per dense block")
                }
            })
            .collect()
    }
}

impl Preconditioner for KfacOptimizer {
    fn name(&self) -> &str {
        KfacOptimizer::name(self)
    }

    fn update_stats(&mut self, _epoch: usize, caps: &[KfacCapture<'_>]) {
        if self.is_factor_update_step() {
            self.update_factors(caps);
        }
    }

    fn refresh(&mut self, epoch: usize) {
        self.refresh_if_due(epoch);
    }

    fn precondition(&mut self, epoch: usize, grads: &[&Matrix]) -> Vec<Matrix> {
        KfacOptimizer::precondition(self, grads, epoch)
    }

    fn advance(&mut self) {
        self.step_count += 1;
    }

    fn lr_wd(&self, epoch: usize) -> (f64, f64) {
        (self.sched.alpha.at(epoch), self.sched.weight_decay)
    }

    fn attach_pipeline(&mut self, cfg: &PipelineConfig) -> bool {
        KfacOptimizer::attach_pipeline(self, cfg.clone())
    }

    fn set_online(&mut self, mode: OnlineMode, correction_every: usize) -> bool {
        KfacOptimizer::set_online(self, mode, correction_every)
    }

    fn apply_strategy_schedule(&mut self, epoch: usize, set: &StrategySchedules) -> bool {
        KfacOptimizer::apply_strategy_schedule(self, epoch, set)
    }

    fn supports_external_factors(&self) -> bool {
        // Externally-computed factors arrive as dense o×o grams — exactly
        // what factored blocks exist to never materialize.
        !self.has_factored_blocks()
    }

    fn save_state(&self) -> Option<Vec<u8>> {
        Some(self.save_state_bytes())
    }

    fn load_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        self.load_state_bytes(bytes)
    }

    fn step_with_factors(
        &mut self,
        epoch: usize,
        a: Vec<Matrix>,
        g: Vec<Matrix>,
        grads: &[&Matrix],
    ) -> Result<Vec<Matrix>, String> {
        if self.has_factored_blocks() {
            return Err(format!(
                "solver '{}' has factored G-side blocks and cannot accept externally-computed \
                 dense factors (set factored.mode = \"off\" for the artifact path)",
                self.name
            ));
        }
        Ok(KfacOptimizer::step_with_factors(self, epoch, a, g, grads))
    }

    fn diagnostics(&self) -> SolverDiagnostics {
        SolverDiagnostics {
            decomp_seconds: self.decomp_seconds,
            n_decomps: self.n_decomps,
            block_ranks: self.current_ranks(),
            pipeline: self.pipeline.as_ref().map(|p| PipelineDiagnostics {
                worker_seconds: p.worker_seconds(),
                queue_wait_seconds: p.queue_wait_seconds(),
                jobs_completed: p.jobs_completed(),
                recovered_jobs: p.recovered_jobs(),
                superseded_jobs: p.superseded_jobs(),
                rounds: p.rounds(),
                queue_depth: p.queue_depth(),
                max_queue_depth: p.max_queue_depth(),
                warming_slots: p.warming(),
                max_staleness: p.max_staleness(self.step_count as u64),
                controller_ranks: p.ranks(),
            }),
        }
    }

    fn spectra(&self) -> Option<FactorSpectra> {
        Some(FactorSpectra { a: self.a_spectra(), g: self.g_spectra() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::models;
    use crate::optim::schedules::StepSchedule;
    use crate::rnla::decomposition;

    fn quick_sched(rank: usize) -> KfacSchedules {
        KfacSchedules {
            rho: 0.9,
            t_ku: 1,
            t_ki: StepSchedule::constant(1.0),
            lambda: StepSchedule::constant(0.1),
            alpha: StepSchedule::constant(0.2),
            rank: StepSchedule::constant(rank as f64),
            oversample: StepSchedule::constant(6.0),
            n_power_iter: 2,
            weight_decay: 0.0,
        }
    }

    /// RS-KFAC with full-dimension rank must match exact K-FAC step-for-step.
    #[test]
    fn rskfac_full_rank_matches_exact_kfac() {
        let mut net = models::mlp(&[12, 10, 10], 1);
        let mut rng = Pcg64::new(2);
        let x = rng.gaussian_matrix(12, 8);
        let labels: Vec<usize> = (0..8).map(|i| i % 10).collect();
        net.train_batch(&x, &labels, true);
        let dims = net.kfac_dims();

        let mut exact =
            KfacOptimizer::new(Arc::new(decomposition::Exact), quick_sched(64), &dims, 3);
        let mut rs = KfacOptimizer::new(Arc::new(decomposition::Rsvd), quick_sched(64), &dims, 3);
        let caps = net.kfac_captures();
        let d_exact = exact.step(0, &caps);
        let d_rs = rs.step(0, &caps);
        for (a, b) in d_exact.iter().zip(d_rs.iter()) {
            assert!(a.rel_err(b) < 1e-6, "rel err {}", a.rel_err(b));
        }
    }

    /// All strategies agree once the EA spectrum has decayed (§3: the decay
    /// develops over time; early identity-dominated factors are exactly the
    /// regime where truncation would be wrong, so we test the decayed one).
    #[test]
    fn randomized_strategies_close_to_exact_on_decaying_spectrum() {
        let mut rng = Pcg64::new(5);
        let decayed_psd = |rng: &mut Pcg64, d: usize| {
            let q = crate::linalg::qr::orthonormalize(&rng.gaussian_matrix(d, d));
            let lam: Vec<f64> = (0..d).map(|i| 2.0 * 0.55f64.powi(i as i32)).collect();
            let mut qd = q.clone();
            gemm::scale_cols(&mut qd, &lam);
            gemm::matmul_nt(&qd, &q)
        };
        let dims = [(24usize, 20usize), (20, 10)];
        let rank = 14; // captures ~0.55^14 ≈ 2e-4 of λ_max — deep tail cut
        let mut exact =
            KfacOptimizer::new(Arc::new(decomposition::Exact), quick_sched(rank), &dims, 6);
        let mut rs = KfacOptimizer::new(Arc::new(decomposition::Rsvd), quick_sched(rank), &dims, 6);
        let mut sre =
            KfacOptimizer::new(Arc::new(decomposition::Srevd), quick_sched(rank), &dims, 6);
        let a: Vec<Matrix> = dims.iter().map(|&(da, _)| decayed_psd(&mut rng, da)).collect();
        let g: Vec<Matrix> = dims.iter().map(|&(_, dg)| decayed_psd(&mut rng, dg)).collect();
        let grads: Vec<Matrix> = dims.iter().map(|&(da, dg)| rng.gaussian_matrix(dg, da)).collect();
        let grad_refs: Vec<&Matrix> = grads.iter().collect();
        let mut nys =
            KfacOptimizer::new(Arc::new(decomposition::Nystrom), quick_sched(rank), &dims, 6);
        let de = exact.step_with_factors(0, a.clone(), g.clone(), &grad_refs);
        let dr = rs.step_with_factors(0, a.clone(), g.clone(), &grad_refs);
        let ds = sre.step_with_factors(0, a.clone(), g.clone(), &grad_refs);
        let dn = nys.step_with_factors(0, a, g, &grad_refs);
        for (((e, r), s), n) in de.iter().zip(dr.iter()).zip(ds.iter()).zip(dn.iter()) {
            assert!(e.rel_err(r) < 0.05, "rsvd err {}", e.rel_err(r));
            assert!(e.rel_err(s) < 0.10, "srevd err {}", e.rel_err(s));
            assert!(e.rel_err(n) < 0.10, "nystrom err {}", e.rel_err(n));
        }
    }

    /// NYS-KFAC correctness: the Nyström strategy's damped low-rank inverse
    /// must approximate exact K-FAC preconditioning on PSD factors, and at
    /// full rank it must recover it to numerical precision.
    #[test]
    fn nystrom_strategy_matches_exact_kfac() {
        let mut rng = Pcg64::new(17);
        let decayed_psd = |rng: &mut Pcg64, d: usize| {
            let q = crate::linalg::qr::orthonormalize(&rng.gaussian_matrix(d, d));
            let lam: Vec<f64> = (0..d).map(|i| 1.5 * 0.6f64.powi(i as i32)).collect();
            let mut qd = q.clone();
            gemm::scale_cols(&mut qd, &lam);
            gemm::matmul_nt(&qd, &q)
        };
        let dims = [(18usize, 14usize)];
        let a: Vec<Matrix> = dims.iter().map(|&(da, _)| decayed_psd(&mut rng, da)).collect();
        let g: Vec<Matrix> = dims.iter().map(|&(_, dg)| decayed_psd(&mut rng, dg)).collect();
        let grads: Vec<Matrix> = dims.iter().map(|&(da, dg)| rng.gaussian_matrix(dg, da)).collect();
        let grad_refs: Vec<&Matrix> = grads.iter().collect();
        // Full-rank Nyström ≡ exact (rank 18 covers both factor dims).
        let mut exact =
            KfacOptimizer::new(Arc::new(decomposition::Exact), quick_sched(18), &dims, 8);
        let mut nys_full =
            KfacOptimizer::new(Arc::new(decomposition::Nystrom), quick_sched(18), &dims, 8);
        let de = exact.step_with_factors(0, a.clone(), g.clone(), &grad_refs);
        let dn = nys_full.step_with_factors(0, a.clone(), g.clone(), &grad_refs);
        for (e, n) in de.iter().zip(dn.iter()) {
            assert!(e.rel_err(n) < 1e-6, "full-rank nystrom err {}", e.rel_err(n));
        }
        // Truncated Nyström stays close on the decayed spectrum.
        let mut nys_r =
            KfacOptimizer::new(Arc::new(decomposition::Nystrom), quick_sched(10), &dims, 8);
        let dr = nys_r.step_with_factors(0, a, g, &grad_refs);
        for (e, r) in de.iter().zip(dr.iter()) {
            assert!(e.rel_err(r) < 0.05, "rank-10 nystrom err {}", e.rel_err(r));
        }
    }

    /// The EA update must be copy-on-write against in-flight pipeline
    /// snapshots: with no outstanding `Arc` clone it blends in place (no
    /// allocation), and with one it reallocates while the snapshot keeps
    /// its original values.
    #[test]
    fn ea_update_is_cow_against_inflight_snapshots() {
        let mut net = models::mlp(&[6, 5, 10], 7);
        let mut rng = Pcg64::new(8);
        let x = rng.gaussian_matrix(6, 4);
        net.train_batch(&x, &[0, 1, 2, 3], true);
        let dims = net.kfac_dims();
        let mut opt = KfacOptimizer::new(Arc::new(decomposition::Exact), quick_sched(6), &dims, 9);
        let caps = net.kfac_captures();
        // No outstanding snapshot: the blend mutates the same allocation.
        let p0 = Arc::as_ptr(&opt.blocks[0].a_bar);
        opt.update_factors(&caps);
        assert_eq!(p0, Arc::as_ptr(&opt.blocks[0].a_bar), "in-place blend expected");
        // A held snapshot (what a pipeline job carries) must keep its
        // values while the trainer blends new statistics.
        let snap = Arc::clone(&opt.blocks[0].a_bar);
        let vals = snap.as_slice().to_vec();
        opt.update_factors(&caps);
        assert_eq!(snap.as_slice(), &vals[..], "snapshot mutated under a live job");
        assert!(
            !Arc::ptr_eq(&snap, &opt.blocks[0].a_bar),
            "trainer must have moved to a private copy"
        );
    }

    #[test]
    fn ea_factors_identity_init_and_blend() {
        let mut net = models::mlp(&[6, 5, 10], 7);
        let mut rng = Pcg64::new(8);
        let x = rng.gaussian_matrix(6, 4);
        net.train_batch(&x, &[0, 1, 2, 3], true);
        let dims = net.kfac_dims();
        let mut opt = KfacOptimizer::new(Arc::new(decomposition::Exact), quick_sched(6), &dims, 9);
        // Before any update: Ā = I.
        assert!(opt.blocks[0].a_bar.rel_err(&Matrix::eye(6)) < 1e-12);
        let caps = net.kfac_captures();
        opt.update_factors(&caps);
        // After: Ā = ρI + (1-ρ)/B · XXᵀ.
        let mut expect = Matrix::eye(6);
        gemm::ea_gram_update(&mut expect, 0.9, &x, 4.0);
        assert!(opt.blocks[0].a_bar.rel_err(&expect) < 1e-12);
    }

    #[test]
    fn t_ku_t_ki_periods_respected() {
        let mut net = models::mlp(&[6, 5, 10], 10);
        let mut rng = Pcg64::new(11);
        let mut sched = quick_sched(6);
        sched.t_ku = 3;
        sched.t_ki = StepSchedule::constant(5.0);
        let dims = net.kfac_dims();
        let mut opt = KfacOptimizer::new(Arc::new(decomposition::Exact), sched, &dims, 12);
        let labels = [0usize, 1, 2, 3];
        for step in 0..10 {
            let x = rng.gaussian_matrix(6, 4);
            net.train_batch(&x, &labels, true);
            let caps = net.kfac_captures();
            let before = opt.n_decomps;
            let _ = opt.step(0, &caps);
            let decomposed = opt.n_decomps > before;
            assert_eq!(decomposed, step % 5 == 0, "step {step}");
        }
    }

    #[test]
    fn preconditioned_step_descends_faster_than_sgd_direction() {
        // On a quadratic-ish local model, K-FAC steps should still reduce
        // loss when applied; sanity: finite + descending over a few steps.
        let mut net = models::mlp(&[10, 8, 10], 13);
        let mut rng = Pcg64::new(14);
        let x = rng.gaussian_matrix(10, 16);
        let labels: Vec<usize> = (0..16).map(|i| i % 10).collect();
        let dims = net.kfac_dims();
        let mut opt = KfacOptimizer::new(Arc::new(decomposition::Rsvd), quick_sched(8), &dims, 15);
        let (loss0, _) = net.train_batch(&x, &labels, true);
        for _ in 0..15 {
            net.train_batch(&x, &labels, true);
            let deltas = {
                let caps = net.kfac_captures();
                opt.step(0, &caps)
            };
            net.apply_steps(&deltas, opt.sched.alpha.at(0), 0.0);
        }
        let (loss1, _) = net.eval_batch(&x, &labels);
        assert!(loss1 < loss0 * 0.8, "{loss0} -> {loss1}");
        assert!(loss1.is_finite());
    }

    /// `[schedules]` overrides change the installed decomposition rank/
    /// oversampling only when an entry matches the engine's strategy, and
    /// clearing (empty set) restores the schedule-derived parameters.
    #[test]
    fn strategy_schedule_override_drives_recompute() {
        use crate::optim::schedules::{StrategySchedule, StrategySchedules};
        let dims = [(16usize, 12usize)];
        let mut sched = quick_sched(6);
        sched.rank = StepSchedule::new(6.0, vec![(2, 4.0)]); // rank 6 → 10 at epoch 2
        let mut opt = KfacOptimizer::new(Arc::new(decomposition::ExactTruncated), sched, &dims, 4);
        let mut set = StrategySchedules::default();
        set.insert(
            "trunc",
            StrategySchedule {
                oversample: Some(StepSchedule::constant(2.0)),
                power_iter: Some(StepSchedule::constant(0.0)),
                target_rel_err: None,
            },
        );
        // Entry matches → override installs; rank follows the global
        // schedule at the applied epoch.
        assert!(opt.apply_strategy_schedule(2, &set));
        opt.recompute_decompositions(2);
        assert_eq!(opt.current_ranks(), vec![(10, 10)]);
        // No entry for this strategy → cleared, schedule rank at epoch 0.
        assert!(!opt.apply_strategy_schedule(0, &StrategySchedules::default()));
        opt.recompute_decompositions(0);
        assert_eq!(opt.current_ranks(), vec![(6, 6)]);
        // A non-matching key is the same as no entry.
        let mut other = StrategySchedules::default();
        other.insert("rsvd", StrategySchedule::default());
        assert!(!opt.apply_strategy_schedule(0, &other));
    }

    /// Checkpoint round-trip: a fresh engine restored from `save_state`
    /// continues the step sequence bitwise — same deltas, same counters,
    /// same decomposition RNG streams (positioned by `n_decomps`).
    #[test]
    fn state_roundtrip_continues_bitwise() {
        let mut net = models::mlp(&[10, 8, 10], 3);
        let mut rng = Pcg64::new(4);
        let dims = net.kfac_dims();
        let mut sched = quick_sched(6);
        sched.t_ki = StepSchedule::constant(2.0);
        let mut donor =
            KfacOptimizer::new(Arc::new(decomposition::Rsvd), sched.clone(), &dims, 5);
        let labels: Vec<usize> = (0..6).map(|i| i % 10).collect();
        let mut batches = Vec::new();
        for _ in 0..7 {
            batches.push(rng.gaussian_matrix(10, 6));
        }
        // Run 3 steps, snapshot, keep going on the donor.
        for x in &batches[..3] {
            net.train_batch(x, &labels, true);
            let caps = net.kfac_captures();
            let _ = donor.step(0, &caps);
        }
        let blob = donor.save_state_bytes();
        let mut restored =
            KfacOptimizer::new(Arc::new(decomposition::Rsvd), sched.clone(), &dims, 5);
        restored.load_state_bytes(&blob).unwrap();
        assert_eq!(restored.step_count, donor.step_count);
        assert_eq!(restored.n_decomps, donor.n_decomps);
        assert_eq!(restored.current_ranks(), donor.current_ranks());
        for x in &batches[3..] {
            net.train_batch(x, &labels, true);
            let caps = net.kfac_captures();
            let da = donor.step(0, &caps);
            let db = restored.step(0, &caps);
            for (a, b) in da.iter().zip(db.iter()) {
                assert_eq!(a.as_slice(), b.as_slice(), "post-restore step must be bitwise");
            }
        }
        // Strategy / shape mismatches fail loudly.
        let mut wrong_strategy =
            KfacOptimizer::new(Arc::new(decomposition::Srevd), sched.clone(), &dims, 5);
        assert!(wrong_strategy.load_state_bytes(&blob).is_err());
        let mut wrong_dims =
            KfacOptimizer::new(Arc::new(decomposition::Rsvd), sched, &[(4, 4)], 5);
        assert!(wrong_dims.load_state_bytes(&blob).is_err());
        // Truncated blob fails loudly.
        let mut fresh = KfacOptimizer::new(
            Arc::new(decomposition::Rsvd),
            quick_sched(6),
            &dims,
            5,
        );
        assert!(fresh.load_state_bytes(&blob[..blob.len() - 9]).is_err());
    }

    #[test]
    fn spectra_probe_shapes() {
        let dims = [(6usize, 5usize), (5, 10)];
        let opt = KfacOptimizer::new(Arc::new(decomposition::Exact), quick_sched(4), &dims, 16);
        let sa = opt.a_spectra();
        assert_eq!(sa.len(), 2);
        assert_eq!(sa[0].len(), 6);
        // Identity factors → all eigenvalues 1.
        assert!(sa[0].iter().all(|&l| (l - 1.0).abs() < 1e-12));
    }

    /// The trainer drives the engine exclusively through the trait: the
    /// phase composition must run the T_KU/T_KI cadence and surface the
    /// engine's counters/ranks/spectra via diagnostics.
    #[test]
    fn trait_phases_drive_engine() {
        let mut net = models::mlp(&[8, 6, 10], 19);
        let mut rng = Pcg64::new(20);
        let dims = net.kfac_dims();
        let mut opt: Box<dyn Preconditioner> =
            Box::new(KfacOptimizer::new(Arc::new(decomposition::Rsvd), quick_sched(5), &dims, 21));
        for _ in 0..4 {
            let x = rng.gaussian_matrix(8, 6);
            let labels = [0usize, 1, 2, 3, 4, 5];
            net.train_batch(&x, &labels, true);
            let caps = net.kfac_captures();
            let deltas = opt.step(0, &caps);
            assert!(deltas.iter().all(|d| d.as_slice().iter().all(|v| v.is_finite())));
        }
        // t_ki = 1 → every step decomposed; rank-5 RSVD installed.
        let diag = opt.diagnostics();
        assert_eq!(diag.n_decomps, 4);
        assert!(diag.decomp_seconds > 0.0);
        assert_eq!(diag.block_ranks, vec![(5, 5), (5, 5)]);
        assert!(diag.pipeline.is_none());
        let spectra = opt.spectra().expect("engine exposes factor spectra");
        assert_eq!(spectra.a.len(), 2);
        assert_eq!(spectra.a[0].len(), 8);
    }
}
