//! Optimizers: the paper's solvers behind one interface.
//!
//! - [`kfac`]: K-FAC / RS-KFAC / SRE-KFAC (one engine, three
//!   [`kfac::Inversion`] strategies — the paper's Algorithms 1, 4, 5).
//! - [`ekfac`]: EK-FAC + randomized variants (§4.3 transfer).
//! - [`seng`]: the SENG baseline (sketched empirical NG, linear in width).
//! - [`sgd`]: SGD with momentum.
//! - [`schedules`]: the §5 hyper-parameter schedules.

pub mod ekfac;
pub mod kfac;
pub mod schedules;
pub mod seng;
pub mod sgd;

pub use ekfac::EkfacOptimizer;
pub use kfac::{Inversion, KfacOptimizer};
pub use schedules::{KfacSchedules, StepSchedule};
pub use seng::{SengConfig, SengOptimizer};
pub use sgd::{SgdConfig, SgdOptimizer};

use crate::linalg::Matrix;
use crate::nn::KfacCapture;
use crate::pipeline::PipelineConfig;

/// Any of the paper's solvers, behind one step interface for the trainer.
pub enum Solver {
    Kfac(KfacOptimizer),
    Ekfac(EkfacOptimizer),
    Seng(SengOptimizer),
    Sgd(SgdOptimizer),
}

impl Solver {
    /// Construct by name: "kfac" | "rs-kfac" | "sre-kfac" | "trunc-kfac" |
    /// "nys-kfac" | "ekfac" | "rs-ekfac" | "sre-ekfac" | "nys-ekfac" |
    /// "seng" | "sgd".
    pub fn by_name(
        name: &str,
        sched: KfacSchedules,
        dims: &[(usize, usize)],
        seed: u64,
    ) -> Result<Solver, String> {
        let s = match name {
            "kfac" => Solver::Kfac(KfacOptimizer::new(Inversion::Exact, sched, dims, seed)),
            "rs-kfac" => Solver::Kfac(KfacOptimizer::new(Inversion::Rsvd, sched, dims, seed)),
            "sre-kfac" => Solver::Kfac(KfacOptimizer::new(Inversion::Srevd, sched, dims, seed)),
            "trunc-kfac" => {
                Solver::Kfac(KfacOptimizer::new(Inversion::ExactTruncated, sched, dims, seed))
            }
            "nys-kfac" => Solver::Kfac(KfacOptimizer::new(Inversion::Nystrom, sched, dims, seed)),
            "ekfac" => Solver::Ekfac(EkfacOptimizer::new(Inversion::Exact, sched, dims, seed)),
            "rs-ekfac" => Solver::Ekfac(EkfacOptimizer::new(Inversion::Rsvd, sched, dims, seed)),
            "sre-ekfac" => Solver::Ekfac(EkfacOptimizer::new(Inversion::Srevd, sched, dims, seed)),
            "nys-ekfac" => {
                Solver::Ekfac(EkfacOptimizer::new(Inversion::Nystrom, sched, dims, seed))
            }
            "seng" => Solver::Seng(SengOptimizer::new(SengConfig::default(), dims.len(), seed)),
            "sgd" => Solver::Sgd(SgdOptimizer::new(SgdConfig::default(), dims.len())),
            other => return Err(format!("unknown solver '{other}'")),
        };
        Ok(s)
    }

    /// Attach the async factor-refresh pipeline to the solver's K-FAC
    /// engine. Returns whether the solver supports it (the K-FAC family
    /// does; SENG/SGD have no decomposition cadence to offload).
    pub fn attach_pipeline(&mut self, cfg: &PipelineConfig) -> bool {
        match self {
            Solver::Kfac(o) => {
                o.attach_pipeline(cfg.clone());
                true
            }
            Solver::Ekfac(o) => {
                o.inner.attach_pipeline(cfg.clone());
                true
            }
            Solver::Seng(_) | Solver::Sgd(_) => false,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Solver::Kfac(o) => o.name(),
            Solver::Ekfac(o) => o.name(),
            Solver::Seng(o) => o.name(),
            Solver::Sgd(o) => o.name(),
        }
    }

    /// Compute per-block weight deltas for this step.
    pub fn step(&mut self, epoch: usize, caps: &[KfacCapture<'_>]) -> Vec<Matrix> {
        match self {
            Solver::Kfac(o) => o.step(epoch, caps),
            Solver::Ekfac(o) => o.step(epoch, caps),
            Solver::Seng(o) => o.step(epoch, caps),
            Solver::Sgd(o) => o.step(epoch, caps),
        }
    }

    /// (lr, weight_decay) to hand `Network::apply_steps` at this epoch.
    pub fn lr_wd(&self, epoch: usize) -> (f64, f64) {
        match self {
            Solver::Kfac(o) => (o.sched.alpha.at(epoch), o.sched.weight_decay),
            Solver::Ekfac(o) => (o.inner.sched.alpha.at(epoch), o.inner.sched.weight_decay),
            Solver::Seng(o) => (o.lr_at(epoch), o.cfg.weight_decay),
            Solver::Sgd(o) => (o.lr_at(epoch), o.cfg.weight_decay),
        }
    }

    /// Seconds spent in factor decompositions so far (K-FAC family only).
    pub fn decomp_seconds(&self) -> f64 {
        match self {
            Solver::Kfac(o) => o.decomp_seconds,
            Solver::Ekfac(o) => o.inner.decomp_seconds,
            _ => 0.0,
        }
    }

    /// Access the inner K-FAC engine (spectrum probes).
    pub fn as_kfac(&self) -> Option<&KfacOptimizer> {
        match self {
            Solver::Kfac(o) => Some(o),
            Solver::Ekfac(o) => Some(&o.inner),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_constructs_all() {
        let dims = [(8usize, 6usize)];
        for name in [
            "kfac", "rs-kfac", "sre-kfac", "trunc-kfac", "nys-kfac", "ekfac", "rs-ekfac",
            "sre-ekfac", "nys-ekfac", "seng", "sgd",
        ] {
            let s = Solver::by_name(name, KfacSchedules::paper(), &dims, 1).unwrap();
            assert_eq!(s.name(), name);
        }
        assert!(Solver::by_name("adam", KfacSchedules::paper(), &dims, 1).is_err());
    }

    #[test]
    fn attach_pipeline_by_solver_family() {
        let dims = [(8usize, 6usize)];
        let cfg = PipelineConfig::default();
        for (name, supported) in
            [("rs-kfac", true), ("nys-kfac", true), ("ekfac", true), ("seng", false), ("sgd", false)]
        {
            let mut s = Solver::by_name(name, KfacSchedules::paper(), &dims, 1).unwrap();
            assert_eq!(s.attach_pipeline(&cfg), supported, "{name}");
        }
    }

    #[test]
    fn lr_wd_reflect_schedules() {
        let dims = [(8usize, 6usize)];
        let s = Solver::by_name("rs-kfac", KfacSchedules::paper(), &dims, 1).unwrap();
        let (lr, wd) = s.lr_wd(0);
        assert!((lr - 0.3).abs() < 1e-12);
        assert!((wd - 7e-4).abs() < 1e-12);
    }
}
