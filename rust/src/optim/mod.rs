//! Optimizers: open solver families behind the [`Preconditioner`] trait.
//!
//! ## Architecture
//!
//! The solver axis of variation is *curvature model × decomposition ×
//! schedule*, and each axis is open:
//!
//! - **Curvature model** — anything implementing [`Preconditioner`]
//!   (`update_stats` / `refresh` / `precondition` / `attach_pipeline` /
//!   `diagnostics`): the K-FAC engine ([`kfac::KfacOptimizer`]), EK-FAC by
//!   composition over it ([`ekfac::EkfacOptimizer`]), the SENG baseline
//!   ([`seng::SengOptimizer`]), momentum SGD ([`sgd::SgdOptimizer`]), or a
//!   third-party backend registered via
//!   [`SolverRegistry::register_family`].
//! - **Decomposition** — any [`crate::rnla::Decomposition`] strategy
//!   (exact, truncated, RSVD, SRE-EVD, Nyström, …) plugged into the K-FAC
//!   engine; see [`crate::rnla::decomposition`].
//! - **Schedule** — the §5 hyper-parameter block ([`schedules`]), plus the
//!   async pipeline's per-layer adaptive rank controller when attached.
//!
//! ## Construction
//!
//! Solvers are built by name through the [`registry`] — canonical
//! `family+strategy` specs (`kfac+rsvd`, `ekfac+nystrom`) or the eleven
//! legacy paper names (`rs-kfac`, `nys-ekfac`, …), which remain aliases:
//!
//! ```text
//! let solver = optim::build_solver("kfac+rsvd", sched, &dims, seed)?;
//! // or, fluent + custom registry:
//! let solver = SolverBuilder::new().schedules(sched).dims(&dims).build("rs-kfac")?;
//! ```
//!
//! The registry path is golden-equivalent (bitwise-identical step deltas)
//! to constructing the concrete optimizers directly — enforced by
//! `rust/tests/registry_golden.rs`.

pub mod ekfac;
pub mod kfac;
pub mod preconditioner;
pub mod registry;
pub mod schedules;
pub mod seng;
pub mod sgd;

pub use ekfac::EkfacOptimizer;
pub use kfac::KfacOptimizer;
pub use preconditioner::{
    FactorSpectra, FactoredMode, FactoredPolicy, PipelineDiagnostics, Preconditioner,
    SolverDiagnostics,
};
pub use registry::{build_solver, LEGACY_SOLVER_NAMES, SolverBuilder, SolverRegistry, SolverSpec};
pub use schedules::{KfacSchedules, StepSchedule, StrategySchedule, StrategySchedules};
pub use seng::{SengConfig, SengOptimizer};
pub use sgd::{SgdConfig, SgdOptimizer};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::PipelineConfig;

    #[test]
    fn build_solver_constructs_all_legacy_names() {
        let dims = [(8usize, 6usize)];
        for name in LEGACY_SOLVER_NAMES {
            let s = build_solver(name, KfacSchedules::paper(), &dims, 1).unwrap();
            assert_eq!(s.name(), name);
        }
        assert!(build_solver("adam", KfacSchedules::paper(), &dims, 1).is_err());
    }

    #[test]
    fn attach_pipeline_by_solver_family() {
        let dims = [(8usize, 6usize)];
        let cfg = PipelineConfig::default();
        for (name, supported) in
            [("rs-kfac", true), ("nys-kfac", true), ("ekfac", true), ("seng", false), ("sgd", false)]
        {
            let mut s = build_solver(name, KfacSchedules::paper(), &dims, 1).unwrap();
            assert_eq!(s.attach_pipeline(&cfg), supported, "{name}");
        }
    }

    #[test]
    fn lr_wd_reflect_schedules() {
        let dims = [(8usize, 6usize)];
        let s = build_solver("rs-kfac", KfacSchedules::paper(), &dims, 1).unwrap();
        let (lr, wd) = s.lr_wd(0);
        assert!((lr - 0.3).abs() < 1e-12);
        assert!((wd - 7e-4).abs() < 1e-12);
    }

    #[test]
    fn external_factor_support_is_kfac_engine_only() {
        let dims = [(8usize, 6usize)];
        for (name, supported) in
            [("kfac", true), ("nys-kfac", true), ("ekfac", false), ("seng", false), ("sgd", false)]
        {
            let s = build_solver(name, KfacSchedules::paper(), &dims, 1).unwrap();
            assert_eq!(s.supports_external_factors(), supported, "{name}");
        }
    }

    #[test]
    fn diagnostics_replace_field_access() {
        let dims = [(8usize, 6usize), (6, 4)];
        let s = build_solver("sre-ekfac", KfacSchedules::paper(), &dims, 1).unwrap();
        let d = s.diagnostics();
        assert_eq!(d.n_decomps, 0);
        assert_eq!(d.decomp_seconds, 0.0);
        assert_eq!(d.block_ranks.len(), 2);
        // Identity-seeded decompositions are full rank before any refresh.
        assert_eq!(d.block_ranks[0], (8, 6));
        let spectra = s.spectra().expect("K-FAC family exposes spectra");
        assert_eq!(spectra.a.len(), 2);
        assert_eq!(spectra.g[1].len(), 4);
        // Baselines have neither.
        let sgd = build_solver("sgd", KfacSchedules::paper(), &dims, 1).unwrap();
        assert!(sgd.spectra().is_none());
        assert!(sgd.diagnostics().block_ranks.is_empty());
    }
}
