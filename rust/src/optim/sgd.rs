//! SGD with momentum — the trivial baseline (the paper omits it from
//! Table 1 because SENG dominates it, but the framework supports it).

use crate::linalg::Matrix;
use crate::nn::KfacCapture;
use crate::optim::preconditioner::Preconditioner;
use crate::util::codec;

#[derive(Clone, Debug)]
pub struct SgdConfig {
    pub lr: f64,
    pub momentum: f64,
    pub weight_decay: f64,
    /// Multiplicative LR decay applied at each epoch in `decay_epochs`.
    pub decay_factor: f64,
    pub decay_epochs: Vec<usize>,
}

impl Default for SgdConfig {
    fn default() -> Self {
        SgdConfig {
            lr: 0.1,
            momentum: 0.9,
            weight_decay: 5e-4,
            decay_factor: 0.1,
            decay_epochs: vec![25, 40],
        }
    }
}

pub struct SgdOptimizer {
    pub cfg: SgdConfig,
    momentum_buf: Vec<Option<Matrix>>,
    pub step_count: usize,
}

impl SgdOptimizer {
    pub fn new(cfg: SgdConfig, n_blocks: usize) -> Self {
        SgdOptimizer { cfg, momentum_buf: (0..n_blocks).map(|_| None).collect(), step_count: 0 }
    }

    pub fn name(&self) -> &'static str {
        "sgd"
    }

    pub fn lr_at(&self, epoch: usize) -> f64 {
        let mut lr = self.cfg.lr;
        for &e in &self.cfg.decay_epochs {
            if epoch >= e {
                lr *= self.cfg.decay_factor;
            }
        }
        lr
    }

    /// Momentum-SGD deltas for all layers (lr folded in).
    fn precondition_grads(&mut self, epoch: usize, grads: &[&Matrix]) -> Vec<Matrix> {
        let lr = self.lr_at(epoch);
        let mut deltas = Vec::with_capacity(grads.len());
        for (i, grad) in grads.iter().enumerate() {
            let mut dir = (*grad).clone();
            if self.cfg.momentum > 0.0 {
                dir = match self.momentum_buf[i].take() {
                    Some(mut m) if m.shape() == dir.shape() => {
                        m.scale_inplace(self.cfg.momentum);
                        m.axpy(1.0, &dir);
                        m
                    }
                    _ => dir,
                };
                self.momentum_buf[i] = Some(dir.clone());
            }
            dir.scale_inplace(-lr);
            deltas.push(dir);
        }
        deltas
    }

    /// Full step (the [`Preconditioner::step`] phase composition).
    pub fn step(&mut self, epoch: usize, caps: &[KfacCapture<'_>]) -> Vec<Matrix> {
        Preconditioner::step(self, epoch, caps)
    }

    /// Serialize the resumable state: step counter + momentum buffers.
    pub fn save_state_bytes(&self) -> Vec<u8> {
        let mut w = codec::ByteWriter::new();
        w.tag(b"SGD1");
        w.u64(self.step_count as u64);
        w.u64(self.momentum_buf.len() as u64);
        for buf in &self.momentum_buf {
            match buf {
                Some(m) => {
                    w.u8(1);
                    w.matrix(m);
                }
                None => w.u8(0),
            }
        }
        w.into_bytes()
    }

    /// Restore [`SgdOptimizer::save_state_bytes`] output.
    pub fn load_state_bytes(&mut self, bytes: &[u8]) -> Result<(), String> {
        let mut r = codec::ByteReader::new(bytes);
        r.tag(b"SGD1")?;
        self.step_count = r.u64()? as usize;
        let n = r.u64()? as usize;
        if n != self.momentum_buf.len() {
            return Err(format!(
                "checkpoint has {n} momentum blocks, this model has {}",
                self.momentum_buf.len()
            ));
        }
        for buf in self.momentum_buf.iter_mut() {
            *buf = if r.u8()? != 0 { Some(r.matrix()?) } else { None };
        }
        r.finish()
    }
}

impl Preconditioner for SgdOptimizer {
    fn name(&self) -> &str {
        SgdOptimizer::name(self)
    }

    fn update_stats(&mut self, _epoch: usize, _caps: &[KfacCapture<'_>]) {}

    fn refresh(&mut self, _epoch: usize) {}

    fn precondition(&mut self, epoch: usize, grads: &[&Matrix]) -> Vec<Matrix> {
        self.precondition_grads(epoch, grads)
    }

    fn advance(&mut self) {
        self.step_count += 1;
    }

    fn lr_wd(&self, epoch: usize) -> (f64, f64) {
        (self.lr_at(epoch), self.cfg.weight_decay)
    }

    fn save_state(&self) -> Option<Vec<u8>> {
        Some(self.save_state_bytes())
    }

    fn load_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        self.load_state_bytes(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Pcg64;
    use crate::nn::models;

    #[test]
    fn sgd_descends() {
        let mut net = models::mlp(&[10, 8, 10], 1);
        let mut rng = Pcg64::new(2);
        let x = rng.gaussian_matrix(10, 8);
        let labels: Vec<usize> = (0..8).map(|i| i % 10).collect();
        let mut opt = SgdOptimizer::new(
            SgdConfig { lr: 0.2, momentum: 0.9, weight_decay: 0.0, ..Default::default() },
            net.kfac_dims().len(),
        );
        let (loss0, _) = net.train_batch(&x, &labels, true);
        for _ in 0..25 {
            net.train_batch(&x, &labels, true);
            let deltas = {
                let caps = net.kfac_captures();
                opt.step(0, &caps)
            };
            net.apply_steps(&deltas, 0.2, 0.0);
        }
        let (loss1, _) = net.eval_batch(&x, &labels);
        assert!(loss1 < loss0 * 0.7, "{loss0} -> {loss1}");
    }

    #[test]
    fn lr_decay_schedule() {
        let opt = SgdOptimizer::new(SgdConfig::default(), 1);
        assert!((opt.lr_at(0) - 0.1).abs() < 1e-12);
        assert!((opt.lr_at(25) - 0.01).abs() < 1e-12);
        assert!((opt.lr_at(40) - 0.001).abs() < 1e-12);
    }

    #[test]
    fn momentum_accumulates() {
        // Constant gradient: with momentum m, step_k → lr·(1+m+…+m^k).
        let mut net = models::mlp(&[4, 10], 3);
        let mut rng = Pcg64::new(4);
        let x = rng.gaussian_matrix(4, 4);
        net.train_batch(&x, &[0, 1, 2, 3], true);
        let mut opt = SgdOptimizer::new(
            SgdConfig { lr: 1.0, momentum: 0.5, weight_decay: 0.0, ..Default::default() },
            1,
        );
        let caps = net.kfac_captures();
        let d1 = opt.step(0, &caps);
        let d2 = opt.step(0, &caps);
        // d2 = -(1.5)·grad, d1 = -grad
        let ratio = d2[0].fro_norm() / d1[0].fro_norm();
        assert!((ratio - 1.5).abs() < 1e-10, "ratio {ratio}");
    }

    /// Checkpoint round-trip: the restored momentum buffers continue the
    /// step sequence bitwise.
    #[test]
    fn state_roundtrip_continues_bitwise() {
        let mut net = models::mlp(&[6, 5, 10], 5);
        let mut rng = Pcg64::new(6);
        let n_blocks = net.kfac_dims().len();
        let mut donor = SgdOptimizer::new(SgdConfig::default(), n_blocks);
        let labels = [0usize, 1, 2, 3];
        for _ in 0..3 {
            let x = rng.gaussian_matrix(6, 4);
            net.train_batch(&x, &labels, true);
            let caps = net.kfac_captures();
            let _ = donor.step(0, &caps);
        }
        let blob = donor.save_state_bytes();
        let mut restored = SgdOptimizer::new(SgdConfig::default(), n_blocks);
        restored.load_state_bytes(&blob).unwrap();
        assert_eq!(restored.step_count, donor.step_count);
        for _ in 0..3 {
            let x = rng.gaussian_matrix(6, 4);
            net.train_batch(&x, &labels, true);
            let caps = net.kfac_captures();
            let da = donor.step(0, &caps);
            let db = restored.step(0, &caps);
            for (a, b) in da.iter().zip(db.iter()) {
                assert_eq!(a.as_slice(), b.as_slice());
            }
        }
        // Block-count mismatch fails loudly.
        let mut wrong = SgdOptimizer::new(SgdConfig::default(), n_blocks + 1);
        assert!(wrong.load_state_bytes(&blob).is_err());
    }
}
