//! EK-FAC (eigenvalue-corrected K-FAC; George et al. / Gao et al.) and its
//! randomized variants — the paper's §4.3 "direct idea transfer".
//!
//! EK-FAC keeps the Kronecker eigenbasis `U_Γ ⊗ U_A` but replaces the
//! Kronecker-product eigenvalues `d_Γ,i · d_A,j` with directly-estimated
//! second moments of the projected gradients:
//!
//! ```text
//!     S_ij = EA[ (U_Γᵀ · Mat(g) · U_A)_ij² ]
//! ```
//!
//! The preconditioned step is `U_Γ [ P ⊘ (S + λ) ] U_Aᵀ` with
//! `P = U_Γᵀ Mat(g) U_A`. With *truncated* bases (rank r from any
//! randomized [`Decomposition`] — the paper's transfer), the component of
//! the gradient outside the retained basis is treated isotropically at
//! scale λ, exactly like eq. (13).
//!
//! EK-FAC *composes over* the K-FAC engine — the inner [`KfacOptimizer`]
//! owns the EA factors and their (possibly randomized) eigenbases and is
//! fully encapsulated here; the trainer reaches EK-FAC state only through
//! the [`Preconditioner`] trait (diagnostics, spectra, pipeline
//! attachment), never through the engine directly. That includes the
//! refresh pipeline's copy-on-write `Arc` factor snapshots and cost-aware
//! scheduling: EK-FAC's `update_stats` delegates to the engine's
//! `Arc::make_mut` EA blend, so its bases ride the same slots and the same
//! zero-copy enqueue path as plain K-FAC.
//!
//! Dense-linalg dispatch: every GEMM below (`P = U_Γᵀ Mat(g) U_A`, the S
//! blend, the reprojection) goes through [`crate::linalg::gemm`] and thus
//! the installed `[linalg]` compute backend — threaded execution and the
//! bitwise-determinism contract come for free, with no code here caring.

use std::sync::Arc;

use crate::linalg::{gemm, Matrix};
use crate::nn::KfacCapture;
use crate::optim::kfac::KfacOptimizer;
use crate::optim::preconditioner::{FactorSpectra, Preconditioner, SolverDiagnostics};
use crate::optim::registry::solver_display_name;
use crate::optim::schedules::KfacSchedules;
use crate::pipeline::PipelineConfig;
use crate::rnla::Decomposition;
use crate::util::codec;

/// EK-FAC state layered on top of a [`KfacOptimizer`] (which provides the
/// EA factors and their — possibly randomized — eigenbases).
pub struct EkfacOptimizer {
    inner: KfacOptimizer,
    /// Display name (`ekfac`/`rs-ekfac`/… for built-in strategies).
    name: String,
    /// Per-block EA of squared projected gradients (r_Γ × r_A).
    s: Vec<Matrix>,
    /// EA decay for the S statistics.
    s_rho: f64,
}

impl EkfacOptimizer {
    pub fn new(
        strategy: Arc<dyn Decomposition>,
        sched: KfacSchedules,
        dims: &[(usize, usize)],
        seed: u64,
    ) -> Self {
        let name = solver_display_name("ekfac", strategy.key());
        let inner = KfacOptimizer::new(strategy, sched, dims, seed);
        let s = inner
            .blocks
            .iter()
            .map(|b| Matrix::ones(b.g_dec.rank(), b.a_dec.rank()))
            .collect();
        EkfacOptimizer { inner, name, s, s_rho: 0.95 }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Refresh the S statistics from the current gradients (every step —
    /// it is cheap: two thin projections per block).
    fn update_s(&mut self, grads: &[&Matrix]) {
        for (bi, (b, g)) in self.inner.blocks.iter().zip(grads.iter()).enumerate() {
            // P = U_Γᵀ g U_A : (r_Γ, r_A)
            let p = gemm::matmul(&gemm::matmul_tn(&b.g_dec.u, g), &b.a_dec.u);
            let p2 = p.map(|v| v * v);
            if self.s[bi].shape() != p2.shape() {
                // Basis rank changed at a T_KI boundary: reset statistics.
                self.s[bi] = p2;
            } else {
                self.s[bi].ea_blend(self.s_rho, &p2);
            }
        }
    }

    /// Precondition with eigenvalue-corrected scaling.
    fn precondition_corrected(&self, grads: &[&Matrix], epoch: usize) -> Vec<Matrix> {
        let lambda = self.inner.sched.lambda.at(epoch);
        let alpha = self.inner.sched.alpha.at(epoch);
        grads
            .iter()
            .enumerate()
            .map(|(bi, g)| {
                let b = &self.inner.blocks[bi];
                let ug = &b.g_dec.u; // (d_Γ, r_Γ)
                let ua = &b.a_dec.u; // (d_A, r_A)
                // P = U_Γᵀ g U_A
                let p = gemm::matmul(&gemm::matmul_tn(ug, g), ua);
                // Core: P ⊘ (S + λ) − P/λ  (the residual identity-part
                // correction, mirroring eq. (13)'s [ (D+λ)^{-1} − λ^{-1} ]).
                let s = &self.s[bi];
                let core = Matrix::from_fn(p.rows(), p.cols(), |i, j| {
                    p[(i, j)] / (s[(i, j)] + lambda) - p[(i, j)] / lambda
                });
                // step = U_Γ core U_Aᵀ + g/λ
                let mut out = gemm::matmul_nt(&gemm::matmul(ug, &core), ua);
                out.axpy(1.0 / lambda, g);
                out.scale_inplace(-alpha);
                out
            })
            .collect()
    }

    /// Decompositions due this step? (Same T_KI cadence as the engine, but
    /// without the engine's mandatory first-step recomputation clause —
    /// step 0 always hits the cadence anyway.)
    fn refresh_if_due(&mut self, epoch: usize) {
        let t_ki = self.inner.sched.t_ki.at(epoch).max(1.0) as usize;
        if self.inner.step_count % t_ki == 0 {
            self.inner.recompute_decompositions(epoch);
        }
    }

    /// Full step (native path): delegates factor/decomposition cadence to
    /// the inner K-FAC, then applies the corrected scaling. One step
    /// sequence only — this is the [`Preconditioner::step`] composition.
    pub fn step(&mut self, epoch: usize, caps: &[KfacCapture<'_>]) -> Vec<Matrix> {
        Preconditioner::step(self, epoch, caps)
    }

    /// Serialize the EK-FAC state: the eigenvalue-correction statistics S
    /// (the George et al. scalings) plus the inner engine's full state as
    /// a nested blob.
    pub fn save_state_bytes(&self) -> Vec<u8> {
        let mut w = codec::ByteWriter::new();
        w.tag(b"EK01");
        w.f64(self.s_rho);
        w.u64(self.s.len() as u64);
        for m in &self.s {
            w.matrix(m);
        }
        w.blob(&self.inner.save_state_bytes());
        w.into_bytes()
    }

    /// Restore [`EkfacOptimizer::save_state_bytes`] output. The S matrices
    /// adopt the checkpointed shapes (they track the — possibly adapted —
    /// basis ranks, not the static config).
    pub fn load_state_bytes(&mut self, bytes: &[u8]) -> Result<(), String> {
        let mut r = codec::ByteReader::new(bytes);
        r.tag(b"EK01")?;
        self.s_rho = r.f64()?;
        let n = r.u64()? as usize;
        if n != self.s.len() {
            return Err(format!(
                "checkpoint has {n} EK-FAC scaling blocks, this model has {}",
                self.s.len()
            ));
        }
        let mut s = Vec::with_capacity(n);
        for _ in 0..n {
            s.push(r.matrix()?);
        }
        let inner_blob = r.blob()?;
        self.inner.load_state_bytes(inner_blob)?;
        self.s = s;
        r.finish()
    }
}

impl Preconditioner for EkfacOptimizer {
    fn name(&self) -> &str {
        EkfacOptimizer::name(self)
    }

    fn update_stats(&mut self, _epoch: usize, caps: &[KfacCapture<'_>]) {
        if self.inner.is_factor_update_step() {
            self.inner.update_factors(caps);
        }
    }

    fn refresh(&mut self, epoch: usize) {
        self.refresh_if_due(epoch);
    }

    fn precondition(&mut self, epoch: usize, grads: &[&Matrix]) -> Vec<Matrix> {
        // The S moments are taken against the *current* (post-refresh)
        // bases, so this runs inside the precondition phase by design.
        self.update_s(grads);
        self.precondition_corrected(grads, epoch)
    }

    fn advance(&mut self) {
        self.inner.step_count += 1;
    }

    fn lr_wd(&self, epoch: usize) -> (f64, f64) {
        (self.inner.sched.alpha.at(epoch), self.inner.sched.weight_decay)
    }

    fn apply_strategy_schedule(
        &mut self,
        epoch: usize,
        set: &crate::optim::schedules::StrategySchedules,
    ) -> bool {
        self.inner.apply_strategy_schedule(epoch, set)
    }

    fn attach_pipeline(&mut self, cfg: &PipelineConfig) -> bool {
        // The inner engine never has factored blocks (EK-FAC is a
        // dense-only family — the registry rejects column-factoring
        // strategies for it), so this always attaches.
        self.inner.attach_pipeline(cfg.clone())
    }

    fn set_online(&mut self, mode: crate::pipeline::OnlineMode, correction_every: usize) -> bool {
        // EK-FAC's rotation/scaling correction reads whatever bases the
        // inner engine installs — incremental or recomputed — so the mode
        // passes straight through.
        self.inner.set_online(mode, correction_every)
    }

    fn save_state(&self) -> Option<Vec<u8>> {
        Some(self.save_state_bytes())
    }

    fn load_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        self.load_state_bytes(bytes)
    }

    fn diagnostics(&self) -> SolverDiagnostics {
        Preconditioner::diagnostics(&self.inner)
    }

    fn spectra(&self) -> Option<FactorSpectra> {
        Preconditioner::spectra(&self.inner)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Pcg64;
    use crate::nn::models;
    use crate::optim::schedules::StepSchedule;
    use crate::rnla::decomposition;

    fn sched(rank: usize) -> KfacSchedules {
        KfacSchedules {
            rho: 0.9,
            t_ku: 1,
            t_ki: StepSchedule::constant(1.0),
            lambda: StepSchedule::constant(0.1),
            alpha: StepSchedule::constant(0.1),
            rank: StepSchedule::constant(rank as f64),
            oversample: StepSchedule::constant(4.0),
            n_power_iter: 2,
            weight_decay: 0.0,
        }
    }

    #[test]
    fn ekfac_step_descends() {
        let mut net = models::mlp(&[10, 8, 10], 1);
        let mut rng = Pcg64::new(2);
        let x = rng.gaussian_matrix(10, 12);
        let labels: Vec<usize> = (0..12).map(|i| i % 10).collect();
        let dims = net.kfac_dims();
        let mut opt = EkfacOptimizer::new(Arc::new(decomposition::Rsvd), sched(8), &dims, 3);
        let (loss0, _) = net.train_batch(&x, &labels, true);
        for _ in 0..20 {
            net.train_batch(&x, &labels, true);
            let deltas = {
                let caps = net.kfac_captures();
                opt.step(0, &caps)
            };
            net.apply_steps(&deltas, 0.1, 0.0);
        }
        let (loss1, _) = net.eval_batch(&x, &labels);
        assert!(loss1 < loss0 * 0.9, "{loss0} -> {loss1}");
    }

    #[test]
    fn s_statistics_track_projected_grad_moments() {
        let mut net = models::mlp(&[8, 6, 10], 4);
        let mut rng = Pcg64::new(5);
        let x = rng.gaussian_matrix(8, 6);
        let labels = [0usize, 1, 2, 3, 4, 5];
        let dims = net.kfac_dims();
        let mut opt = EkfacOptimizer::new(Arc::new(decomposition::Exact), sched(6), &dims, 6);
        net.train_batch(&x, &labels, true);
        let caps = net.kfac_captures();
        let _ = opt.step(0, &caps);
        // After one step, S = blend(1, p²) must be positive everywhere.
        for s in &opt.s {
            assert!(s.as_slice().iter().all(|&v| v > 0.0));
        }
    }

    /// Checkpoint round-trip: the restored EK-FAC (S statistics + inner
    /// engine) continues the step sequence bitwise.
    #[test]
    fn state_roundtrip_continues_bitwise() {
        let mut net = models::mlp(&[8, 6, 10], 11);
        let mut rng = Pcg64::new(12);
        let dims = net.kfac_dims();
        let mut donor = EkfacOptimizer::new(Arc::new(decomposition::Rsvd), sched(5), &dims, 13);
        let labels = [0usize, 1, 2, 3, 4, 5];
        let mut batches = Vec::new();
        for _ in 0..6 {
            batches.push(rng.gaussian_matrix(8, 6));
        }
        for x in &batches[..3] {
            net.train_batch(x, &labels, true);
            let caps = net.kfac_captures();
            let _ = donor.step(0, &caps);
        }
        let blob = donor.save_state_bytes();
        let mut restored =
            EkfacOptimizer::new(Arc::new(decomposition::Rsvd), sched(5), &dims, 13);
        restored.load_state_bytes(&blob).unwrap();
        for (a, b) in restored.s.iter().zip(donor.s.iter()) {
            assert_eq!(a.as_slice(), b.as_slice(), "S statistics must restore bitwise");
        }
        for x in &batches[3..] {
            net.train_batch(x, &labels, true);
            let caps = net.kfac_captures();
            let da = donor.step(0, &caps);
            let db = restored.step(0, &caps);
            for (a, b) in da.iter().zip(db.iter()) {
                assert_eq!(a.as_slice(), b.as_slice(), "post-restore step must be bitwise");
            }
        }
        // A K-FAC blob is not an EK-FAC blob: cross-family restore fails.
        let kfac_blob = KfacOptimizer::new(Arc::new(decomposition::Rsvd), sched(5), &dims, 13)
            .save_state_bytes();
        let mut fresh = EkfacOptimizer::new(Arc::new(decomposition::Rsvd), sched(5), &dims, 13);
        assert!(fresh.load_state_bytes(&kfac_blob).is_err());
    }

    #[test]
    fn names() {
        let dims = [(4usize, 4usize)];
        assert_eq!(
            EkfacOptimizer::new(Arc::new(decomposition::Rsvd), sched(4), &dims, 1).name(),
            "rs-ekfac"
        );
        assert_eq!(
            EkfacOptimizer::new(Arc::new(decomposition::Exact), sched(4), &dims, 1).name(),
            "ekfac"
        );
    }

    /// The trait surface is the only way the trainer reaches EK-FAC state:
    /// stepping through it runs the inner engine's cadence, and
    /// diagnostics/spectra expose its counters — no `pub inner`.
    #[test]
    fn trait_phases_drive_composed_engine() {
        let mut net = models::mlp(&[8, 6, 10], 7);
        let mut rng = Pcg64::new(8);
        let dims = net.kfac_dims();
        let mut opt: Box<dyn Preconditioner> =
            Box::new(EkfacOptimizer::new(Arc::new(decomposition::Srevd), sched(5), &dims, 9));
        for _ in 0..4 {
            let x = rng.gaussian_matrix(8, 6);
            let labels = [0usize, 1, 2, 3, 4, 5];
            net.train_batch(&x, &labels, true);
            let caps = net.kfac_captures();
            let deltas = opt.step(0, &caps);
            assert!(deltas.iter().all(|d| d.as_slice().iter().all(|v| v.is_finite())));
        }
        let diag = opt.diagnostics();
        assert_eq!(diag.n_decomps, 4);
        assert_eq!(diag.block_ranks.len(), 2);
        assert!(opt.spectra().is_some());
    }
}
