//! SENG baseline — sketched empirical natural gradient (Yang et al. 2021),
//! the O(d_M)-in-width comparator of the paper's Table 1.
//!
//! Per layer, the empirical Fisher is `F = (1/B) Σ_b vec(ĝ_b) vec(ĝ_b)ᵀ`
//! where `ĝ_b = g_b a_bᵀ` is the per-sample weight gradient. SENG never
//! materializes the (d_out·d_in)² Fisher: with `V = [vec(ĝ_1) … vec(ĝ_B)]`
//! the natural direction solves `(VVᵀ/B + λI) s = g` by Sherman–Morrison–
//! Woodbury through the B×B core `VᵀV`, whose entries factor through the
//! Khatri–Rao structure:
//!
//! ```text
//!     (VᵀV)_{bb'} = (g_bᵀ g_{b'}) · (a_bᵀ a_{b'})
//! ```
//!
//! i.e. the Hadamard product of the two B×B grams — O(B²(d_out+d_in)),
//! *linear* in layer width. Matrix sketching (the "S" of SENG) subsamples
//! feature coordinates (`fim_col_sample_size`) when computing the grams,
//! matching the official implementation's knob.
//!
//! Dense-linalg dispatch: the gram builds (`matmul_tn`), the SMW chain
//! (`matmul`/`matmul_nt`) and the Cholesky core solve all route through
//! [`crate::linalg::gemm`]/[`crate::linalg::chol`] and therefore the
//! installed `[linalg]` backend. SENG has no sketch-GEMM path, so
//! `precision = "mixed"` is a no-op here (allowed but inert).

use crate::linalg::{chol, gemm, Matrix, Pcg64};
use crate::nn::KfacCapture;
use crate::optim::preconditioner::Preconditioner;
use crate::util::codec;

/// SENG hyper-parameters (defaults follow the paper's §5 footnote 10 where
/// they transfer: damping 2.0 is the official CIFAR10/VGG16 setting).
#[derive(Clone, Debug)]
pub struct SengConfig {
    pub lr: f64,
    pub damping: f64,
    pub weight_decay: f64,
    pub momentum: f64,
    /// Feature subsampling size for the gram sketches (official default 128).
    pub col_sample: usize,
    /// Curvature (gram) refresh period in steps (official: 200).
    pub update_freq: usize,
    /// Exponential LR decay rate per epoch fraction (lr_scheme = 'exp').
    pub lr_decay_rate: f64,
    pub lr_decay_epoch: usize,
}

impl Default for SengConfig {
    fn default() -> Self {
        SengConfig {
            lr: 0.05,
            damping: 2.0,
            weight_decay: 1e-2,
            momentum: 0.9,
            col_sample: 128,
            update_freq: 200,
            lr_decay_rate: 6.0,
            lr_decay_epoch: 75,
        }
    }
}

/// Cached per-layer curvature: the sampled factor columns defining the
/// sketched empirical Fisher at the last refresh.
struct LayerCurvature {
    /// Sampled A rows (a_cols ⊂ features) per batch column: (B, B) gram a.
    gram: Matrix,
    /// The factor snapshots for applying V and Vᵀ.
    a: Matrix,
    g: Matrix,
}

/// SENG optimizer over the Kronecker-blocked layers (BN params get plain
/// SGD via `Network::apply_steps`, same as the K-FAC family).
pub struct SengOptimizer {
    pub cfg: SengConfig,
    curv: Vec<Option<LayerCurvature>>,
    momentum_buf: Vec<Option<Matrix>>,
    pub step_count: usize,
    rng: Pcg64,
}

impl SengOptimizer {
    pub fn new(cfg: SengConfig, n_blocks: usize, seed: u64) -> Self {
        SengOptimizer {
            cfg,
            curv: (0..n_blocks).map(|_| None).collect(),
            momentum_buf: (0..n_blocks).map(|_| None).collect(),
            step_count: 0,
            rng: Pcg64::with_stream(seed, 4242),
        }
    }

    pub fn name(&self) -> &'static str {
        "seng"
    }

    /// Learning rate with the official exponential decay scheme.
    pub fn lr_at(&self, epoch: usize) -> f64 {
        let t = (epoch as f64 / self.cfg.lr_decay_epoch as f64).min(1.0);
        self.cfg.lr * (-self.cfg.lr_decay_rate * t).exp()
    }

    /// Subsampled gram `XᵀX̃` where X̃ keeps `col_sample` random rows,
    /// rescaled to be unbiased: (d/k)·Σ_{sampled rows}.
    fn sketched_gram(&mut self, x: &Matrix) -> Matrix {
        let d = x.rows();
        let k = self.cfg.col_sample.min(d);
        if k == d {
            return gemm::matmul_tn(x, x);
        }
        let idx = self.rng.sample_indices(d, k);
        let mut xs = Matrix::zeros(k, x.cols());
        for (r, &i) in idx.iter().enumerate() {
            xs.row_mut(r).copy_from_slice(x.row(i));
        }
        let mut gram = gemm::matmul_tn(&xs, &xs);
        gram.scale_inplace(d as f64 / k as f64);
        gram
    }

    fn refresh_curvature(&mut self, caps: &[KfacCapture<'_>]) {
        for (i, c) in caps.iter().enumerate() {
            // Khatri–Rao gram: (GᵀG) ∘ (AᵀA), both sketched.
            let ga = self.sketched_gram(c.a);
            let gg = self.sketched_gram(c.g);
            let n = c.a.cols();
            let gram = Matrix::from_fn(n, n, |p, q| gg[(p, q)] * ga[(p, q)]);
            self.curv[i] = Some(LayerCurvature { gram, a: c.a.clone(), g: c.g.clone() });
        }
    }

    /// Natural-gradient direction for one layer via SMW.
    ///
    /// `(VVᵀ/B + λI)^{-1} grad = grad/λ − (1/λ²) V (B·I + VᵀV/λ)^{-1} Vᵀgrad`
    /// with `Vᵀgrad`_b = g_bᵀ·Mat(grad)·a_b and `V w = Σ_b w_b g_b a_bᵀ`.
    fn direction(curv: &LayerCurvature, lambda: f64, grad: &Matrix) -> Matrix {
        let b = curv.a.cols();
        // vt_g[b] = g_bᵀ grad a_b — compute as diag(Gᵀ (grad A)).
        let grad_a = gemm::matmul(grad, &curv.a); // (d_out, B)
        let mut vt_g = vec![0.0; b];
        for bi in 0..b {
            let mut acc = 0.0;
            for r in 0..grad_a.rows() {
                acc += curv.g[(r, bi)] * grad_a[(r, bi)];
            }
            vt_g[bi] = acc;
        }
        // Core solve: (B·I + VᵀV/λ) w = vt_g  — B×B SPD (gram cached).
        let mut core = curv.gram.clone();
        core.scale_inplace(1.0 / lambda);
        core.add_diag(b as f64);
        let w = chol::spd_solve(&core, &Matrix::col_vector(&vt_g))
            .expect("SENG core solve failed (non-SPD sketched gram)");
        // V w = Σ_b w_b g_b a_bᵀ = G diag(w) Aᵀ.
        let mut gw = curv.g.clone();
        let wv: Vec<f64> = (0..b).map(|i| w[(i, 0)]).collect();
        gemm::scale_cols(&mut gw, &wv);
        let vw = gemm::matmul_nt(&gw, &curv.a);
        // grad/λ − vw/λ².
        let mut out = grad.clone();
        out.scale_inplace(1.0 / lambda);
        out.axpy(-1.0 / (lambda * lambda), &vw);
        out
    }

    /// Refresh the cached per-layer curvature when the update period (or a
    /// missing cache) makes it due.
    fn refresh_curvature_if_due(&mut self, caps: &[KfacCapture<'_>]) {
        if self.step_count % self.cfg.update_freq == 0 || self.curv.iter().any(Option::is_none) {
            self.refresh_curvature(caps);
        }
    }

    /// Natural-gradient deltas for all layers (momentum + lr folded in;
    /// weight decay folds in via `Network::apply_steps`).
    fn precondition_grads(&mut self, epoch: usize, grads: &[&Matrix]) -> Vec<Matrix> {
        let lr = self.lr_at(epoch);
        let mut deltas = Vec::with_capacity(grads.len());
        for (i, grad) in grads.iter().enumerate() {
            let curv = self.curv[i].as_ref().expect("SENG curvature missing (no update_stats?)");
            let mut dir = Self::direction(curv, self.cfg.damping, grad);
            // Momentum on the preconditioned direction.
            if self.cfg.momentum > 0.0 {
                let buf = self.momentum_buf[i].take();
                let mut m = match buf {
                    Some(mut m) if m.shape() == dir.shape() => {
                        m.scale_inplace(self.cfg.momentum);
                        m.axpy(1.0, &dir);
                        m
                    }
                    _ => dir.clone(),
                };
                dir = m.clone();
                m.scale_inplace(1.0);
                self.momentum_buf[i] = Some(m);
            }
            dir.scale_inplace(-lr);
            deltas.push(dir);
        }
        deltas
    }

    /// Full step: returns per-block weight deltas (the
    /// [`Preconditioner::step`] phase composition).
    pub fn step(&mut self, epoch: usize, caps: &[KfacCapture<'_>]) -> Vec<Matrix> {
        Preconditioner::step(self, epoch, caps)
    }

    /// Serialize the resumable state: step counter, the sketch RNG (so the
    /// next curvature refresh draws the same sample indices), the cached
    /// per-layer curvature (gram + factor snapshots), and the momentum
    /// buffers.
    pub fn save_state_bytes(&self) -> Vec<u8> {
        let mut w = codec::ByteWriter::new();
        w.tag(b"SG01");
        w.u64(self.step_count as u64);
        let (state, inc) = self.rng.raw_state();
        w.u128(state);
        w.u128(inc);
        w.u64(self.curv.len() as u64);
        for c in &self.curv {
            match c {
                Some(c) => {
                    w.u8(1);
                    w.matrix(&c.gram);
                    w.matrix(&c.a);
                    w.matrix(&c.g);
                }
                None => w.u8(0),
            }
        }
        for buf in &self.momentum_buf {
            match buf {
                Some(m) => {
                    w.u8(1);
                    w.matrix(m);
                }
                None => w.u8(0),
            }
        }
        w.into_bytes()
    }

    /// Restore [`SengOptimizer::save_state_bytes`] output on a
    /// freshly-built SENG of the same model. Continuing the step loop
    /// afterwards reproduces the uninterrupted run bitwise: refresh
    /// cadence (step counter), sketch sampling (RNG), and the directions
    /// (curvature + momentum) all resume from the checkpointed state.
    pub fn load_state_bytes(&mut self, bytes: &[u8]) -> Result<(), String> {
        let mut r = codec::ByteReader::new(bytes);
        r.tag(b"SG01")?;
        let step_count = r.u64()? as usize;
        let state = r.u128()?;
        let inc = r.u128()?;
        let n = r.u64()? as usize;
        if n != self.curv.len() {
            return Err(format!(
                "checkpoint has {n} SENG curvature blocks, this model has {}",
                self.curv.len()
            ));
        }
        let mut curv = Vec::with_capacity(n);
        for _ in 0..n {
            curv.push(match r.u8()? {
                0 => None,
                _ => Some(LayerCurvature {
                    gram: r.matrix()?,
                    a: r.matrix()?,
                    g: r.matrix()?,
                }),
            });
        }
        let mut momentum = Vec::with_capacity(n);
        for _ in 0..n {
            momentum.push(match r.u8()? {
                0 => None,
                _ => Some(r.matrix()?),
            });
        }
        r.finish()?;
        self.step_count = step_count;
        self.rng = Pcg64::from_raw(state, inc);
        self.curv = curv;
        self.momentum_buf = momentum;
        Ok(())
    }
}

impl Preconditioner for SengOptimizer {
    fn name(&self) -> &str {
        SengOptimizer::name(self)
    }

    fn update_stats(&mut self, _epoch: usize, caps: &[KfacCapture<'_>]) {
        self.refresh_curvature_if_due(caps);
    }

    fn refresh(&mut self, _epoch: usize) {}

    fn precondition(&mut self, epoch: usize, grads: &[&Matrix]) -> Vec<Matrix> {
        self.precondition_grads(epoch, grads)
    }

    fn advance(&mut self) {
        self.step_count += 1;
    }

    fn lr_wd(&self, epoch: usize) -> (f64, f64) {
        (self.lr_at(epoch), self.cfg.weight_decay)
    }

    fn save_state(&self) -> Option<Vec<u8>> {
        Some(self.save_state_bytes())
    }

    fn load_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        self.load_state_bytes(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::models;

    #[test]
    fn direction_matches_dense_woodbury_small() {
        // Dense reference on a tiny layer: F = VVᵀ/B + λI over vec(W).
        let mut rng = Pcg64::new(1);
        let (d_out, d_in, b) = (3usize, 4usize, 5usize);
        let a = rng.gaussian_matrix(d_in, b);
        let g = rng.gaussian_matrix(d_out, b);
        let grad = rng.gaussian_matrix(d_out, d_in);
        let lambda = 0.7;
        // Build V explicitly: column b is vec(g_b a_bᵀ) (row-major vec).
        let mut v = Matrix::zeros(d_out * d_in, b);
        for bi in 0..b {
            for r in 0..d_out {
                for c in 0..d_in {
                    v[(r * d_in + c, bi)] = g[(r, bi)] * a[(c, bi)];
                }
            }
        }
        let x_ref = chol::woodbury_solve(&v, b as f64, lambda, &Matrix::col_vector(grad.as_slice()))
            .unwrap();
        // SENG path (no sketching: col_sample huge).
        let gram_a = gemm::matmul_tn(&a, &a);
        let gram_g = gemm::matmul_tn(&g, &g);
        let gram = Matrix::from_fn(b, b, |p, q| gram_g[(p, q)] * gram_a[(p, q)]);
        let curv = LayerCurvature { gram, a: a.clone(), g: g.clone() };
        let dir = SengOptimizer::direction(&curv, lambda, &grad);
        for r in 0..d_out {
            for c in 0..d_in {
                let want = x_ref[(r * d_in + c, 0)];
                assert!(
                    (dir[(r, c)] - want).abs() < 1e-9 * want.abs().max(1.0),
                    "({r},{c}): {} vs {want}",
                    dir[(r, c)]
                );
            }
        }
    }

    #[test]
    fn seng_step_descends() {
        let mut net = models::mlp(&[12, 10, 10], 2);
        let mut rng = Pcg64::new(3);
        let x = rng.gaussian_matrix(12, 16);
        let labels: Vec<usize> = (0..16).map(|i| i % 10).collect();
        let cfg = SengConfig { lr: 0.3, momentum: 0.0, update_freq: 1, ..Default::default() };
        let mut opt = SengOptimizer::new(cfg, net.kfac_dims().len(), 4);
        let (loss0, _) = net.train_batch(&x, &labels, true);
        for _ in 0..25 {
            net.train_batch(&x, &labels, true);
            let deltas = {
                let caps = net.kfac_captures();
                opt.step(0, &caps)
            };
            net.apply_steps(&deltas, 0.3, 0.0);
        }
        let (loss1, _) = net.eval_batch(&x, &labels);
        assert!(loss1 < loss0 * 0.8, "{loss0} -> {loss1}");
    }

    #[test]
    fn lr_decays_exponentially() {
        let opt = SengOptimizer::new(SengConfig::default(), 1, 5);
        assert!((opt.lr_at(0) - 0.05).abs() < 1e-12);
        assert!(opt.lr_at(10) < opt.lr_at(0));
        assert!(opt.lr_at(75) < opt.lr_at(10));
        // Decay saturates at lr_decay_epoch.
        assert!((opt.lr_at(75) - opt.lr_at(100)).abs() < 1e-15);
    }

    /// Save/load round-trips every piece of resumable state: the restored
    /// optimizer's next step is bitwise-identical to the uninterrupted
    /// one's, and mismatched or truncated blobs fail loudly.
    #[test]
    fn state_roundtrip_resumes_bitwise() {
        let mut net = models::mlp(&[12, 10, 10], 2);
        let mut rng = Pcg64::new(9);
        let x = rng.gaussian_matrix(12, 16);
        let labels: Vec<usize> = (0..16).map(|i| i % 10).collect();
        // Small col_sample so the sketch RNG actually draws (and must be
        // restored); update_freq 2 so the post-restore step exercises the
        // cached-curvature path too.
        let cfg = SengConfig { col_sample: 8, update_freq: 2, ..Default::default() };
        let mut opt = SengOptimizer::new(cfg.clone(), net.kfac_dims().len(), 4);
        for _ in 0..3 {
            net.train_batch(&x, &labels, true);
            let deltas = {
                let caps = net.kfac_captures();
                opt.step(0, &caps)
            };
            net.apply_steps(&deltas, 0.3, 0.0);
        }
        let blob = opt.save_state_bytes();
        let mut restored = SengOptimizer::new(cfg.clone(), net.kfac_dims().len(), 4);
        restored.load_state_bytes(&blob).unwrap();
        assert_eq!(restored.step_count, opt.step_count);
        // Two more steps cover a no-refresh step (count 3) and a refresh
        // step (count 4, drawing fresh sketch indices from the RNG).
        for _ in 0..2 {
            net.train_batch(&x, &labels, true);
            let caps = net.kfac_captures();
            let d1 = opt.step(0, &caps);
            let d2 = restored.step(0, &caps);
            assert_eq!(d1.len(), d2.len());
            for (a, b) in d1.iter().zip(&d2) {
                assert_eq!(a.as_slice(), b.as_slice(), "resumed step must be bitwise");
            }
        }
        // Wrong block count and truncated blobs fail loudly.
        let mut wrong = SengOptimizer::new(cfg.clone(), 1, 4);
        assert!(wrong.load_state_bytes(&blob).is_err());
        let mut trunc = SengOptimizer::new(cfg, net.kfac_dims().len(), 4);
        assert!(trunc.load_state_bytes(&blob[..blob.len() - 3]).is_err());
    }

    #[test]
    fn sketched_gram_unbiased_scale() {
        let mut opt = SengOptimizer::new(
            SengConfig { col_sample: 64, ..Default::default() },
            1,
            6,
        );
        let mut rng = Pcg64::new(7);
        let x = rng.gaussian_matrix(512, 8);
        let exact = gemm::matmul_tn(&x, &x);
        // Average many sketches: should approach the exact gram.
        let mut acc = Matrix::zeros(8, 8);
        let trials = 60;
        for _ in 0..trials {
            acc.axpy(1.0 / trials as f64, &opt.sketched_gram(&x));
        }
        assert!(acc.rel_err(&exact) < 0.2, "rel err {}", acc.rel_err(&exact));
    }
}
