//! The [`Preconditioner`] trait: one open interface over every solver.
//!
//! The trainer drives any curvature model — K-FAC family, EK-FAC, SENG,
//! SGD, or a third-party backend registered through
//! [`crate::optim::registry::SolverRegistry`] — through this trait instead
//! of a closed enum. A step decomposes into four phases, and the provided
//! [`Preconditioner::step`] runs them in the canonical order:
//!
//! 1. [`update_stats`](Preconditioner::update_stats) — absorb fresh
//!    curvature statistics from the batch captures when due (EA gram blends
//!    on the T_KU cadence, SENG gram refreshes, …);
//! 2. [`refresh`](Preconditioner::refresh) — recompute derived quantities
//!    when due (factor decompositions on the T_KI cadence — inline, or via
//!    the attached async pipeline);
//! 3. [`precondition`](Preconditioner::precondition) — map gradients to
//!    per-block weight deltas with the current curvature state;
//! 4. [`advance`](Preconditioner::advance) — advance the step counter.
//!
//! Observability goes through [`diagnostics`](Preconditioner::diagnostics)
//! (cheap counters/ranks) and [`spectra`](Preconditioner::spectra)
//! (expensive exact EVD probes, K-FAC family only) — there is no more
//! downcasting to a concrete engine from the trainer.

use crate::linalg::Matrix;
use crate::nn::KfacCapture;
use crate::optim::schedules::StrategySchedules;
use crate::pipeline::{OnlineMode, PipelineConfig};

/// Which blocks route their G-side through the factored (Woodbury /
/// sketched-core) solve instead of the dense eigen path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FactoredMode {
    /// No factored solves — the engine is bitwise the legacy eigen path.
    Off,
    /// Every block's G-side is factored (vocab-scale heads everywhere).
    All,
    /// Blocks whose G-side width is at least
    /// [`FactoredPolicy::width_threshold`] are factored; narrower blocks
    /// keep the eigen path. A threshold of `usize::MAX` routes nothing and
    /// is bitwise ≡ `Off` (the golden-suite anchor).
    Hybrid,
}

/// The width-policy layer: which blocks get factored G-side solves, with
/// which core strategy, and under what retained-column budget. Parsed from
/// the `[factored]` config section; consumed by
/// [`crate::optim::registry::SolverRegistry::build_with_factored`].
#[derive(Clone, Debug, PartialEq)]
pub struct FactoredPolicy {
    pub mode: FactoredMode,
    /// Minimum G-side width (output dimension) for a block to be factored
    /// under [`FactoredMode::Hybrid`].
    pub width_threshold: usize,
    /// Core strategy key — must name a registered [`crate::rnla::Decomposition`]
    /// whose `factors_columns()` is true (`"woodbury"` or `"sketchcore"`).
    pub core: String,
    /// Retained-column window: the EA recursion keeps at most this many
    /// columns of `R_t` (oldest — most ρ-discounted — trimmed first).
    /// Memory per factored block is O(o · max_cols) vs the dense O(o²).
    pub max_cols: usize,
    /// Sketched-core row budget (ignored by exact-core strategies).
    pub col_sample: usize,
}

impl Default for FactoredPolicy {
    fn default() -> Self {
        FactoredPolicy {
            mode: FactoredMode::Off,
            width_threshold: 4096,
            core: "woodbury".into(),
            max_cols: 256,
            col_sample: 64,
        }
    }
}

impl FactoredPolicy {
    /// Whether a block with G-side width `d_g` routes to the factored path.
    pub fn routes_to_factored(&self, d_g: usize) -> bool {
        match self.mode {
            FactoredMode::Off => false,
            FactoredMode::All => true,
            FactoredMode::Hybrid => d_g >= self.width_threshold,
        }
    }

    /// Whether the policy can never route anything (the bitwise-legacy
    /// fast path).
    pub fn is_off(&self) -> bool {
        self.mode == FactoredMode::Off
            || (self.mode == FactoredMode::Hybrid && self.width_threshold == usize::MAX)
    }
}

/// Cheap observability snapshot of a solver (safe to poll every step).
#[derive(Clone, Debug, Default)]
pub struct SolverDiagnostics {
    /// Wall seconds the *step loop* has spent blocked on decompositions.
    pub decomp_seconds: f64,
    /// Decomposition-refresh rounds completed so far.
    pub n_decomps: usize,
    /// Installed per-block decomposition ranks `(rank_A, rank_Γ)` (empty
    /// for solvers without Kronecker-factor decompositions).
    pub block_ranks: Vec<(usize, usize)>,
    /// Async refresh-pipeline statistics, when one is attached.
    pub pipeline: Option<PipelineDiagnostics>,
}

/// Stats of an attached [`crate::pipeline::FactorPipeline`].
#[derive(Clone, Debug)]
pub struct PipelineDiagnostics {
    /// Total seconds workers spent inside decompositions (overlapped with
    /// training when the staleness budget is nonzero).
    pub worker_seconds: f64,
    /// Total seconds jobs sat in the scheduler queue before a worker popped
    /// them — disjoint from `worker_seconds` (the two used to be conflated).
    pub queue_wait_seconds: f64,
    pub jobs_completed: usize,
    /// Jobs whose worker failed (or whose worker pool died) and which
    /// completed via the trainer-thread inline retry instead of aborting
    /// training.
    pub recovered_jobs: usize,
    /// In-flight jobs replaced by a re-enqueue after the rank controller
    /// changed the target rank before they published.
    pub superseded_jobs: usize,
    pub rounds: usize,
    /// Jobs waiting in the scheduler queue right now.
    pub queue_depth: usize,
    /// High-water mark of the queue depth (sampled after each enqueue
    /// round).
    pub max_queue_depth: usize,
    /// Slots that have never published a decomposition (mid-warmup). These
    /// are *excluded* from `max_staleness` rather than collapsing it.
    pub warming_slots: usize,
    /// Worst staleness (steps) across published slots at the current step;
    /// `None` before any slot has published.
    pub max_staleness: Option<u64>,
    /// Adaptive controller rank per slot (block-major, A then Γ).
    pub controller_ranks: Vec<usize>,
}

/// Exact eigen-spectra of the EA K-factors (Fig. 1 probes; O(d³) per
/// block — diagnostics only, never the training hot path).
#[derive(Clone, Debug)]
pub struct FactorSpectra {
    /// Per-block descending eigenvalues of Ā.
    pub a: Vec<Vec<f64>>,
    /// Per-block descending eigenvalues of Γ̄.
    pub g: Vec<Vec<f64>>,
}

/// A curvature-aware optimizer behind the trainer's step interface.
pub trait Preconditioner {
    /// Display name — the legacy solver names (`rs-kfac`, …) for built-in
    /// configurations, `family+strategy` for novel combinations.
    fn name(&self) -> &str;

    /// Absorb fresh curvature statistics from this step's captures, if due.
    fn update_stats(&mut self, epoch: usize, caps: &[KfacCapture<'_>]);

    /// Recompute derived quantities (decompositions, solves) if due.
    fn refresh(&mut self, epoch: usize);

    /// Map gradients to per-block weight deltas (includes the −α scaling;
    /// weight decay is applied by `Network::apply_steps`).
    fn precondition(&mut self, epoch: usize, grads: &[&Matrix]) -> Vec<Matrix>;

    /// Advance the internal step counter (end of one optimization step).
    fn advance(&mut self);

    /// One full step in the canonical phase order.
    fn step(&mut self, epoch: usize, caps: &[KfacCapture<'_>]) -> Vec<Matrix> {
        self.update_stats(epoch, caps);
        self.refresh(epoch);
        let grads: Vec<&Matrix> = caps.iter().map(|c| c.grad).collect();
        let deltas = self.precondition(epoch, &grads);
        self.advance();
        deltas
    }

    /// `(lr, weight_decay)` to hand `Network::apply_steps` at this epoch.
    fn lr_wd(&self, epoch: usize) -> (f64, f64);

    /// Route decomposition refreshes through the async factor pipeline.
    /// Returns whether the solver supports it (only solvers with a
    /// decomposition cadence do).
    fn attach_pipeline(&mut self, _cfg: &PipelineConfig) -> bool {
        false
    }

    /// Switch decomposition refreshes to online incremental basis
    /// maintenance (`[pipeline] online`): EA updates are captured as
    /// low-rank deltas and refreshes rotate the installed eigenbasis
    /// instead of recomputing it, with a mandatory full decomposition
    /// every `correction_every` rounds. Returns whether the solver (and
    /// its decomposition strategy) actually supports the mode — `false`
    /// leaves the recompute-from-scratch path bitwise in place, which is
    /// also the default for solvers without a decomposition cadence.
    fn set_online(&mut self, _mode: OnlineMode, _correction_every: usize) -> bool {
        false
    }

    /// Install the `[schedules]` per-strategy sketch overrides for `epoch`
    /// (resolved through the strategy's
    /// [`tune`](crate::rnla::Decomposition::tune) hook — see
    /// [`StrategySchedules::sketch_for`]). Called by the session at every
    /// epoch boundary; returns whether an override now applies. The default
    /// no-op covers solvers without a decomposition axis, and an empty set
    /// (or one without an entry for this solver's strategy) must leave the
    /// cadence bitwise-untouched.
    fn apply_strategy_schedule(&mut self, _epoch: usize, _set: &StrategySchedules) -> bool {
        false
    }

    /// Whether [`step_with_factors`](Preconditioner::step_with_factors) is
    /// available (the PJRT artifact path checks this up front).
    fn supports_external_factors(&self) -> bool {
        false
    }

    /// Step with externally-computed EA factors (the PJRT artifact path:
    /// the `ea_gram` Pallas kernel already blended them). Errs for solvers
    /// without Kronecker-factor state.
    fn step_with_factors(
        &mut self,
        _epoch: usize,
        _a: Vec<Matrix>,
        _g: Vec<Matrix>,
        _grads: &[&Matrix],
    ) -> Result<Vec<Matrix>, String> {
        Err(format!("solver '{}' does not accept externally-computed factors", self.name()))
    }

    /// Serialize the solver's full training state for a checkpoint: K-FAC
    /// EA factors and their installed decompositions, step / refresh-round
    /// counters (which also position the per-(round, block, side)
    /// decomposition RNG streams), EK-FAC scaling statistics, SGD momentum,
    /// and — when a pipeline is attached — the slot versions. `None` means
    /// the solver has nothing to persist beyond the network parameters;
    /// [`load_state`](Preconditioner::load_state) must accept exactly what
    /// this produced. The encoding is the solver's own business (the
    /// checkpoint file stores it as an opaque section).
    fn save_state(&self) -> Option<Vec<u8>> {
        None
    }

    /// Restore state produced by [`save_state`](Preconditioner::save_state)
    /// on a freshly-built solver of the same configuration. After a
    /// successful restore, continuing the step loop reproduces the
    /// uninterrupted run bitwise (for solvers whose steps are deterministic
    /// given their state). The default errs: a solver without persistence
    /// support must fail a resume loudly, not continue with cold state.
    fn load_state(&mut self, _bytes: &[u8]) -> Result<(), String> {
        Err(format!(
            "solver '{}' does not support checkpoint state restore (resume would silently \
             restart with cold statistics)",
            self.name()
        ))
    }

    /// Cheap counters/ranks snapshot.
    fn diagnostics(&self) -> SolverDiagnostics {
        SolverDiagnostics::default()
    }

    /// Exact factor spectra (`None` for solvers without EA K-factors).
    fn spectra(&self) -> Option<FactorSpectra> {
        None
    }
}
