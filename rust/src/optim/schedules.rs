//! Hyper-parameter schedules — §5 "Implementation Details".
//!
//! The paper drives K-FAC-family solvers with epoch-indexed step schedules:
//!
//! - `T_KI(e) = 50 − 20·1[e≥20]`            (inverse recomputation period)
//! - `λ_K(e) = 0.1 − 0.05·1[e≥25] − 0.04·1[e≥35]`   (K-factor damping)
//! - `α(e)  = 0.3 − 0.1·1[e≥2] − 0.1·1[e≥3] − 0.07·1[e≥13] − 0.02·1[e≥18]
//!            − 0.007·1[e≥27] − 0.002·1[e≥40]`       (learning rate)
//! - `r(e)  = 220 + 10·1[e≥15]`             (RSVD/SREVD target rank)
//! - `r_l(e) = 10 + 1[e≥22] + 1[e≥30]`      (oversampling)
//!
//! [`StepSchedule`] expresses exactly this "base − Σ deltas·1[e≥tᵢ]" shape;
//! `scaled(frac)` compresses the epoch axis so shorter runs traverse the
//! same phase structure.
//!
//! On top of the global block, [`StrategySchedules`] holds *per-strategy*
//! epoch-indexed overrides for the sketch parameters (the `[schedules]`
//! TOML section): an experiment can give RSVD and SRE-EVD different
//! oversampling / power-iteration trajectories, routed through each
//! strategy's [`Decomposition::tune`](crate::rnla::Decomposition::tune)
//! hook once per epoch by the session.

use std::collections::BTreeMap;

use crate::rnla::{Decomposition, SketchConfig};

/// Piecewise-constant schedule: `base + Σ delta_i · 1[epoch ≥ at_i]`.
#[derive(Clone, Debug, PartialEq)]
pub struct StepSchedule {
    pub base: f64,
    pub steps: Vec<(usize, f64)>,
}

impl StepSchedule {
    pub fn constant(v: f64) -> Self {
        StepSchedule { base: v, steps: vec![] }
    }

    pub fn new(base: f64, steps: Vec<(usize, f64)>) -> Self {
        StepSchedule { base, steps }
    }

    /// Value at the given epoch.
    pub fn at(&self, epoch: usize) -> f64 {
        let mut v = self.base;
        for &(e, d) in &self.steps {
            if epoch >= e {
                v += d;
            }
        }
        v
    }

    /// Compress the epoch axis by `frac` (e.g. original 50-epoch schedule,
    /// frac = 10/50 → thresholds scaled to a 10-epoch run).
    pub fn scaled(&self, frac: f64) -> StepSchedule {
        StepSchedule {
            base: self.base,
            steps: self
                .steps
                .iter()
                .map(|&(e, d)| (((e as f64) * frac).round() as usize, d))
                .collect(),
        }
    }
}

/// The complete K-FAC-family hyper-parameter block of §5.
#[derive(Clone, Debug)]
pub struct KfacSchedules {
    /// EA decay ρ (paper: 0.95).
    pub rho: f64,
    /// K-factor update period T_KU in steps (paper: 10).
    pub t_ku: usize,
    /// Inverse/decomposition recomputation period T_KI in steps, by epoch.
    pub t_ki: StepSchedule,
    /// K-factor damping λ_K by epoch.
    pub lambda: StepSchedule,
    /// Learning rate α by epoch.
    pub alpha: StepSchedule,
    /// Target rank r by epoch (randomized solvers only).
    pub rank: StepSchedule,
    /// Oversampling r_l by epoch (randomized solvers only).
    pub oversample: StepSchedule,
    /// Power iterations n_pwr-it (paper: 4).
    pub n_power_iter: usize,
    /// Weight decay (paper: 7e-4).
    pub weight_decay: f64,
}

impl KfacSchedules {
    /// The paper's exact 50-epoch CIFAR10/VGG16_bn settings.
    pub fn paper() -> Self {
        KfacSchedules {
            rho: 0.95,
            t_ku: 10,
            t_ki: StepSchedule::new(50.0, vec![(20, -20.0)]),
            lambda: StepSchedule::new(0.1, vec![(25, -0.05), (35, -0.04)]),
            alpha: StepSchedule::new(
                0.3,
                vec![
                    (2, -0.1),
                    (3, -0.1),
                    (13, -0.07),
                    (18, -0.02),
                    (27, -0.007),
                    (40, -0.002),
                ],
            ),
            rank: StepSchedule::new(220.0, vec![(15, 10.0)]),
            oversample: StepSchedule::new(10.0, vec![(22, 1.0), (30, 1.0)]),
            n_power_iter: 4,
            weight_decay: 7e-4,
        }
    }

    /// Paper schedules compressed onto an `epochs`-epoch run, with the rank
    /// schedule rescaled for layers of width ~`max_width` (the paper's 220
    /// modes assume 512-wide layers; keep the same width fraction).
    pub fn scaled(epochs: usize, max_width: usize) -> Self {
        let p = Self::paper();
        let frac = epochs as f64 / 50.0;
        let rank_frac = (max_width as f64 / 512.0).min(1.0);
        KfacSchedules {
            rho: p.rho,
            t_ku: p.t_ku,
            t_ki: p.t_ki.scaled(frac),
            lambda: p.lambda.scaled(frac),
            alpha: p.alpha.scaled(frac),
            rank: StepSchedule::new(
                (220.0 * rank_frac).max(8.0).round(),
                vec![(((15.0 * frac).round()) as usize, (10.0 * rank_frac).round())],
            ),
            oversample: p.oversample.scaled(frac),
            n_power_iter: p.n_power_iter,
            weight_decay: p.weight_decay,
        }
    }
}

/// Epoch-indexed sketch-parameter overrides for one decomposition strategy
/// (one `<strategy>_*` key group of the `[schedules]` TOML section). Any
/// field left `None` falls back to the global [`KfacSchedules`] value.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StrategySchedule {
    /// Oversampling r_l by epoch.
    pub oversample: Option<StepSchedule>,
    /// Power-iteration count n_pwr-it by epoch.
    pub power_iter: Option<StepSchedule>,
    /// Relative-error target handed to [`Decomposition::tune`]. Defaults to
    /// a tight 1e-6, which makes the built-in strategies keep the scheduled
    /// power-iteration count instead of relaxing it.
    pub target_rel_err: Option<f64>,
}

/// Per-strategy epoch-indexed sketch schedules, keyed by
/// [`Decomposition::key`] (the `[schedules]` TOML section). The session
/// routes these through the strategy's `tune` hook at every epoch
/// boundary; strategies without an entry keep the global §5 schedule, so
/// an empty set is exactly the pre-override behaviour.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StrategySchedules {
    entries: BTreeMap<String, StrategySchedule>,
}

impl StrategySchedules {
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Install (or replace) the schedule for `strategy_key`.
    pub fn insert(&mut self, strategy_key: &str, sched: StrategySchedule) {
        self.entries.insert(strategy_key.to_string(), sched);
    }

    pub fn get(&self, strategy_key: &str) -> Option<&StrategySchedule> {
        self.entries.get(strategy_key)
    }

    /// Strategy keys with an override entry, sorted.
    pub fn keys(&self) -> Vec<&str> {
        self.entries.keys().map(String::as_str).collect()
    }

    /// Resolve the sketch parameters `strategy` should use at `epoch`:
    /// scheduled rank from the global block, oversample / power-iter from
    /// this strategy's entry (global fallback), then routed through the
    /// strategy's [`Decomposition::tune`] hook, which gets the final say.
    /// `None` when no entry exists for the strategy — the caller keeps the
    /// pre-override cadence untouched.
    pub fn sketch_for(
        &self,
        strategy: &dyn Decomposition,
        sched: &KfacSchedules,
        epoch: usize,
    ) -> Option<SketchConfig> {
        let e = self.entries.get(strategy.key())?;
        let rank = sched.rank.at(epoch).max(1.0) as usize;
        let oversample = e
            .oversample
            .as_ref()
            .unwrap_or(&sched.oversample)
            .at(epoch)
            .max(0.0) as usize;
        let n_power_iter = match &e.power_iter {
            Some(s) => s.at(epoch).max(0.0) as usize,
            None => sched.n_power_iter,
        };
        let base = SketchConfig::new(rank, oversample, n_power_iter);
        Some(strategy.tune(&base, rank, e.target_rel_err.unwrap_or(1e-6)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_t_ki() {
        let s = KfacSchedules::paper();
        assert_eq!(s.t_ki.at(0), 50.0);
        assert_eq!(s.t_ki.at(19), 50.0);
        assert_eq!(s.t_ki.at(20), 30.0);
        assert_eq!(s.t_ki.at(49), 30.0);
    }

    #[test]
    fn paper_lambda() {
        let s = KfacSchedules::paper();
        assert!((s.lambda.at(0) - 0.1).abs() < 1e-12);
        assert!((s.lambda.at(25) - 0.05).abs() < 1e-12);
        assert!((s.lambda.at(35) - 0.01).abs() < 1e-12);
    }

    #[test]
    fn paper_alpha_monotone_decreasing() {
        let s = KfacSchedules::paper();
        let mut last = f64::INFINITY;
        for e in 0..50 {
            let a = s.alpha.at(e);
            assert!(a <= last + 1e-12);
            assert!(a > 0.0);
            last = a;
        }
        assert!((s.alpha.at(0) - 0.3).abs() < 1e-12);
        assert!((s.alpha.at(45) - 0.001).abs() < 1e-9);
    }

    #[test]
    fn paper_rank_and_oversample() {
        let s = KfacSchedules::paper();
        assert_eq!(s.rank.at(0), 220.0);
        assert_eq!(s.rank.at(15), 230.0);
        assert_eq!(s.oversample.at(0), 10.0);
        assert_eq!(s.oversample.at(31), 12.0);
    }

    #[test]
    fn scaled_preserves_phase_structure() {
        let s = KfacSchedules::scaled(10, 512);
        // 50-epoch thresholds compressed 5×: T_KI drops at epoch 4.
        assert_eq!(s.t_ki.at(3), 50.0);
        assert_eq!(s.t_ki.at(4), 30.0);
        // Rank stays 220 for 512-wide nets.
        assert_eq!(s.rank.at(0), 220.0);
        // Narrower nets get proportionally smaller ranks.
        let s2 = KfacSchedules::scaled(10, 256);
        assert_eq!(s2.rank.at(0), 110.0);
    }

    #[test]
    fn strategy_schedules_resolve_per_epoch() {
        use crate::rnla::decomposition::{Exact, Rsvd};
        let mut set = StrategySchedules::default();
        assert!(set.is_empty());
        set.insert(
            "rsvd",
            StrategySchedule {
                oversample: Some(StepSchedule::new(6.0, vec![(3, 4.0)])),
                power_iter: Some(StepSchedule::new(4.0, vec![(5, -2.0)])),
                target_rel_err: None,
            },
        );
        let sched = KfacSchedules::paper();
        // No entry → None: strategies without overrides keep the §5 cadence.
        assert!(set.sketch_for(&Exact, &sched, 0).is_none());
        // Epoch 0: base (rank 220, r_l 6, n_pwr 4); Rsvd::tune at the tight
        // default ε keeps the power iters and floors oversampling at
        // (rank+9)/10 = 22 > 6.
        let s0 = set.sketch_for(&Rsvd, &sched, 0).unwrap();
        assert_eq!((s0.rank, s0.oversample, s0.n_power_iter), (220, 22, 4));
        // Epoch 5: power-iter schedule dropped to 2.
        let s5 = set.sketch_for(&Rsvd, &sched, 5).unwrap();
        assert_eq!(s5.n_power_iter, 2);
        assert_eq!(set.keys(), vec!["rsvd"]);
    }

    #[test]
    fn strategy_schedule_falls_back_to_global_block() {
        use crate::rnla::decomposition::Exact;
        let mut set = StrategySchedules::default();
        // Entry with no overrides at all: global oversample/power-iter pass
        // through the strategy's tune hook (Exact keeps base verbatim).
        set.insert("exact", StrategySchedule::default());
        let sched = KfacSchedules::paper();
        let s = set.sketch_for(&Exact, &sched, 0).unwrap();
        assert_eq!((s.rank, s.oversample, s.n_power_iter), (220, 10, 4));
        // Epoch 22: the global oversample schedule steps 10 → 11.
        let s22 = set.sketch_for(&Exact, &sched, 22).unwrap();
        assert_eq!(s22.oversample, 11);
    }
}
