//! Solver registry + builder: names → [`Preconditioner`] instances.
//!
//! A solver is specified as `family` or `family+strategy`
//! ([`SolverSpec`]) — e.g. `kfac+rsvd`, `ekfac+nystrom`, `seng` — where
//! `strategy` resolves against a [`DecompositionRegistry`] and `family`
//! against the [`SolverRegistry`]'s factory table. The eleven legacy names
//! (`kfac`, `rs-kfac`, `sre-kfac`, `trunc-kfac`, `nys-kfac`, `ekfac`,
//! `rs-ekfac`, `sre-ekfac`, `nys-ekfac`, `seng`, `sgd`) are kept as
//! aliases, and solvers built through them are golden-equivalent — bitwise
//! identical step deltas — to direct construction of the concrete
//! optimizers (see `rust/tests/registry_golden.rs`).
//!
//! New backends register without editing core files:
//!
//! ```text
//! let mut reg = SolverRegistry::with_defaults();
//! reg.register_decomposition(Arc::new(MyDecomposition));   // kfac+mykey
//! reg.register_family("mysolver", |ctx| Ok(Box::new(...))); // mysolver+...
//! ```

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::optim::ekfac::EkfacOptimizer;
use crate::optim::kfac::KfacOptimizer;
use crate::optim::preconditioner::{FactoredPolicy, Preconditioner};
use crate::optim::schedules::KfacSchedules;
use crate::optim::seng::{SengConfig, SengOptimizer};
use crate::optim::sgd::{SgdConfig, SgdOptimizer};
use crate::pipeline::PipelineConfig;
use crate::rnla::{Decomposition, DecompositionRegistry};

/// A parsed solver name: `family` plus an optional decomposition strategy
/// key (`kfac+rsvd` → family `kfac`, strategy `rsvd`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SolverSpec {
    pub family: String,
    pub strategy: Option<String>,
}

/// The one source of truth for the historical naming scheme: `(strategy
/// key, legacy name prefix)` — a legacy solver name is `<prefix>-<family>`
/// for these strategies and the bare family name for `exact`. Both
/// [`SolverSpec::parse`] and [`solver_display_name`] derive from this
/// table, so the two directions cannot drift apart.
const LEGACY_STRATEGY_PREFIXES: [(&str, &str); 4] =
    [("rsvd", "rs"), ("srevd", "sre"), ("trunc", "trunc"), ("nystrom", "nys")];

/// Families the legacy `<prefix>-<family>` names exist for.
const LEGACY_PREFIXED_FAMILIES: [&str; 2] = ["kfac", "ekfac"];

impl SolverSpec {
    /// Parse `family`, `family+strategy`, or a legacy alias. Unknown bare
    /// names pass through as a family with no strategy — the registry
    /// rejects them at build time if no such family is registered.
    pub fn parse(name: &str) -> Result<SolverSpec, String> {
        let name = name.trim();
        if name.is_empty() {
            return Err("empty solver name".into());
        }
        if let Some((family, strategy)) = name.split_once('+') {
            if family.is_empty() || strategy.is_empty() {
                return Err(format!("malformed solver spec '{name}' (want family+strategy)"));
            }
            return Ok(SolverSpec { family: family.into(), strategy: Some(strategy.into()) });
        }
        if LEGACY_PREFIXED_FAMILIES.contains(&name) {
            // Bare "kfac"/"ekfac" are the exact-EVD solvers of the paper.
            return Ok(SolverSpec { family: name.into(), strategy: Some("exact".into()) });
        }
        for (key, prefix) in LEGACY_STRATEGY_PREFIXES {
            if let Some(family) = name
                .strip_prefix(prefix)
                .and_then(|rest| rest.strip_prefix('-'))
                .filter(|f| LEGACY_PREFIXED_FAMILIES.contains(f))
            {
                return Ok(SolverSpec { family: family.into(), strategy: Some(key.into()) });
            }
        }
        Ok(SolverSpec { family: name.into(), strategy: None })
    }

    /// Canonical `family+strategy` / `family` form.
    pub fn canonical(&self) -> String {
        match &self.strategy {
            Some(s) => format!("{}+{s}", self.family),
            None => self.family.clone(),
        }
    }
}

/// Historical display name for a `(family, strategy)` pair: the paper's
/// solver names for the built-in strategies, `family+key` otherwise.
/// Exact inverse of the alias handling in [`SolverSpec::parse`] (both
/// read [`LEGACY_STRATEGY_PREFIXES`]).
pub fn solver_display_name(family: &str, strategy_key: &str) -> String {
    if strategy_key == "exact" {
        return family.to_string();
    }
    match LEGACY_STRATEGY_PREFIXES.iter().find(|(key, _)| *key == strategy_key) {
        Some((_, prefix)) => format!("{prefix}-{family}"),
        None => format!("{family}+{strategy_key}"),
    }
}

/// Everything a family factory needs to construct its solver.
pub struct SolverBuildCtx<'a> {
    pub spec: &'a SolverSpec,
    /// Resolved decomposition strategy, when the spec names one.
    pub strategy: Option<Arc<dyn Decomposition>>,
    pub sched: &'a KfacSchedules,
    /// `dims[l] = (d_A, d_Γ)` per Kronecker block.
    pub dims: &'a [(usize, usize)],
    pub seed: u64,
    /// Factored width policy from the `[factored]` config section (default
    /// = off). Families without a factored G-side path may ignore it —
    /// except dense-only-marked families, which
    /// [`SolverRegistry::build_with_factored`] rejects up front when the
    /// policy would route one of their blocks.
    pub factored: FactoredPolicy,
    /// The policy's resolved core strategy (`woodbury`/`sketchcore`…) when
    /// the policy is active; `None` otherwise.
    pub factored_core: Option<Arc<dyn Decomposition>>,
}

type SolverFactory =
    dyn Fn(&SolverBuildCtx<'_>) -> Result<Box<dyn Preconditioner>, String> + Send + Sync;

/// Open solver-family table plus the decomposition registry the `+key`
/// suffixes resolve against. Cloning shares the registered factories
/// (`Arc`), so a sweep can hand each worker its own handle cheaply.
#[derive(Clone)]
pub struct SolverRegistry {
    families: BTreeMap<String, Arc<SolverFactory>>,
    decompositions: DecompositionRegistry,
    /// Families known to reject a `+strategy` suffix (built-in: seng, sgd).
    /// [`validate_spec`](SolverRegistry::validate_spec) rejects
    /// `family+strategy` for these up front; re-registering such a family
    /// clears the mark (third-party factories default to permissive, with
    /// the factory itself as the arbiter at build time).
    no_axis_families: std::collections::BTreeSet<String>,
    /// Families that require dense factor state — mapped to the *reason*,
    /// cited when a column-factored strategy (`woodbury`/`sketchcore`) or
    /// an active factored width policy is requested for them (built-in:
    /// ekfac). Cleared by re-registering the family.
    dense_only_families: BTreeMap<String, String>,
}

impl SolverRegistry {
    /// Registry with no families and no strategies.
    pub fn empty() -> Self {
        SolverRegistry {
            families: BTreeMap::new(),
            decompositions: DecompositionRegistry::empty(),
            no_axis_families: Default::default(),
            dense_only_families: Default::default(),
        }
    }

    /// The built-in families (`kfac`, `ekfac`, `seng`, `sgd`) over the
    /// default decomposition strategies.
    pub fn with_defaults() -> Self {
        let mut r = SolverRegistry {
            families: BTreeMap::new(),
            decompositions: DecompositionRegistry::with_defaults(),
            no_axis_families: Default::default(),
            dense_only_families: Default::default(),
        };
        r.register_family("kfac", |ctx: &SolverBuildCtx<'_>| {
            let strategy = ctx
                .strategy
                .clone()
                .ok_or_else(|| "kfac needs a strategy suffix (e.g. kfac+rsvd)".to_string())?;
            let solver = KfacOptimizer::with_policy(
                strategy,
                ctx.factored_core.clone(),
                ctx.sched.clone(),
                ctx.dims,
                ctx.seed,
                ctx.factored.clone(),
            )?;
            Ok(Box::new(solver) as Box<dyn Preconditioner>)
        });
        r.register_family("ekfac", |ctx: &SolverBuildCtx<'_>| {
            let strategy = ctx
                .strategy
                .clone()
                .ok_or_else(|| "ekfac needs a strategy suffix (e.g. ekfac+rsvd)".to_string())?;
            Ok(Box::new(EkfacOptimizer::new(strategy, ctx.sched.clone(), ctx.dims, ctx.seed))
                as Box<dyn Preconditioner>)
        });
        r.register_family("seng", |ctx: &SolverBuildCtx<'_>| {
            reject_strategy(ctx)?;
            Ok(Box::new(SengOptimizer::new(SengConfig::default(), ctx.dims.len(), ctx.seed))
                as Box<dyn Preconditioner>)
        });
        r.register_family("sgd", |ctx: &SolverBuildCtx<'_>| {
            reject_strategy(ctx)?;
            Ok(Box::new(SgdOptimizer::new(SgdConfig::default(), ctx.dims.len()))
                as Box<dyn Preconditioner>)
        });
        r.no_axis_families.insert("seng".into());
        r.no_axis_families.insert("sgd".into());
        r.mark_dense_only(
            "ekfac",
            "EK-FAC rescales an explicit truncated eigenbasis; a column-factored solve exposes \
             no basis to rescale",
        );
        r
    }

    /// Mark `family` as requiring dense factor state, with the reason
    /// cited when a column-factored strategy or an active factored width
    /// policy is requested for it. Re-registering the family clears the
    /// mark.
    pub fn mark_dense_only(&mut self, family: &str, reason: &str) {
        self.dense_only_families.insert(family.to_string(), reason.to_string());
    }

    /// Register (or replace) a solver family under `name`.
    pub fn register_family<F>(&mut self, name: &str, factory: F)
    where
        F: Fn(&SolverBuildCtx<'_>) -> Result<Box<dyn Preconditioner>, String>
            + Send
            + Sync
            + 'static,
    {
        self.families.insert(name.to_string(), Arc::new(factory));
        // Unknown factories default to permissive: the factory decides at
        // build time whether it takes a strategy suffix.
        self.no_axis_families.remove(name);
        self.dense_only_families.remove(name);
    }

    /// Register a decomposition strategy under its own key, making it
    /// buildable as `kfac+<key>` / `ekfac+<key>`.
    pub fn register_decomposition(&mut self, d: Arc<dyn Decomposition>) {
        self.decompositions.register(d);
    }

    pub fn decompositions(&self) -> &DecompositionRegistry {
        &self.decompositions
    }

    /// Registered family names, sorted.
    pub fn families(&self) -> Vec<&str> {
        self.families.keys().map(String::as_str).collect()
    }

    /// The canonical `family+strategy` / bare-family specs this registry
    /// can resolve (for error messages and `--help`-style listings). Every
    /// family is listed bare; families with a decomposition axis also
    /// appear once per registered strategy. Legacy aliases are not
    /// enumerated — they normalize onto these.
    pub fn known_specs(&self) -> Vec<String> {
        let mut out = Vec::new();
        for family in self.families.keys() {
            out.push(family.clone());
            // Families marked strategy-less stay bare; everything else
            // (built-in kfac/ekfac and third-party families alike) is
            // expanded over the registered strategies.
            if !self.no_axis_families.contains(family) {
                let dense_only = self.dense_only_families.contains_key(family);
                for key in self.decompositions.keys() {
                    // Column-factored strategies apply only to families
                    // that can hold factored G-side state: `kfac+woodbury`
                    // is listed, `ekfac+woodbury` is not (and is rejected
                    // with the family's reason by `validate_spec`).
                    let factors_columns =
                        self.decompositions.get(key).is_some_and(|d| d.factors_columns());
                    if dense_only && factors_columns {
                        continue;
                    }
                    out.push(format!("{family}+{key}"));
                }
            }
        }
        out
    }

    /// Check that `name` resolves to a known family and (when one is
    /// named) a known decomposition strategy, without building a solver —
    /// what the `[registry]` config section runs at experiment-resolve
    /// time. The error lists the known specs so a typo is a one-read fix.
    pub fn validate_spec(&self, name: &str) -> Result<SolverSpec, String> {
        let spec = SolverSpec::parse(name)?;
        if !self.families.contains_key(&spec.family) {
            return Err(format!(
                "unknown solver '{name}' (family '{}' is not registered; known specs: {})",
                spec.family,
                self.known_specs().join(", ")
            ));
        }
        if let Some(key) = &spec.strategy {
            if self.no_axis_families.contains(&spec.family) {
                return Err(format!(
                    "solver family '{}' has no decomposition axis (got '+{key}' in '{name}'; \
                     known specs: {})",
                    spec.family,
                    self.known_specs().join(", ")
                ));
            }
            let Some(d) = self.decompositions.get(key) else {
                return Err(format!(
                    "unknown decomposition '{key}' in solver '{name}' (known specs: {})",
                    self.known_specs().join(", ")
                ));
            };
            if d.factors_columns() {
                if let Some(reason) = self.dense_only_families.get(&spec.family) {
                    return Err(format!(
                        "solver family '{}' cannot use column-factored strategy '{key}': \
                         {reason} (known specs: {})",
                        spec.family,
                        self.known_specs().join(", ")
                    ));
                }
            }
        }
        Ok(spec)
    }

    /// Build a solver from a name/spec string (factored width policy off).
    pub fn build(
        &self,
        name: &str,
        sched: KfacSchedules,
        dims: &[(usize, usize)],
        seed: u64,
    ) -> Result<Box<dyn Preconditioner>, String> {
        self.build_with_factored(name, sched, dims, seed, &FactoredPolicy::default())
    }

    /// Build a solver with a factored width policy (the `[factored]`
    /// config section). Resolves the policy's core strategy against the
    /// decomposition registry, rejects dense-only families whose blocks
    /// the policy would route, and hands both to the family factory.
    pub fn build_with_factored(
        &self,
        name: &str,
        sched: KfacSchedules,
        dims: &[(usize, usize)],
        seed: u64,
        factored: &FactoredPolicy,
    ) -> Result<Box<dyn Preconditioner>, String> {
        let spec = SolverSpec::parse(name)?;
        let factory = self.families.get(&spec.family).ok_or_else(|| {
            format!("unknown solver '{name}' (families: {})", self.families().join(", "))
        })?;
        let strategy = match &spec.strategy {
            Some(key) => Some(self.decompositions.get(key).ok_or_else(|| {
                format!(
                    "unknown decomposition '{key}' in solver '{name}' (strategies: {})",
                    self.decompositions.keys().join(", ")
                )
            })?),
            None => None,
        };
        if let Some(reason) = self.dense_only_families.get(&spec.family) {
            if strategy.as_ref().is_some_and(|s| s.factors_columns()) {
                return Err(format!(
                    "solver family '{}' cannot use column-factored strategy '{}': {reason}",
                    spec.family,
                    spec.strategy.as_deref().unwrap_or_default()
                ));
            }
            if dims.iter().any(|&(_, dg)| factored.routes_to_factored(dg)) {
                return Err(format!(
                    "the factored width policy routes a block of solver family '{}', which \
                     requires dense factor state: {reason} (set factored.mode = \"off\" for \
                     this solver)",
                    spec.family
                ));
            }
        }
        let factored_core = if factored.mode != crate::optim::preconditioner::FactoredMode::Off {
            let core = self.decompositions.get(&factored.core).ok_or_else(|| {
                format!(
                    "factored.core '{}' is not a registered decomposition (column-factoring \
                     strategies: {})",
                    factored.core,
                    self.column_factoring_keys().join(", ")
                )
            })?;
            if !core.factors_columns() {
                return Err(format!(
                    "factored.core '{}' is a dense decomposition — it cannot consume gradient \
                     columns (column-factoring strategies: {})",
                    factored.core,
                    self.column_factoring_keys().join(", ")
                ));
            }
            Some(core)
        } else {
            None
        };
        factory(&SolverBuildCtx {
            spec: &spec,
            strategy,
            sched: &sched,
            dims,
            seed,
            factored: factored.clone(),
            factored_core,
        })
    }

    /// Keys of registered strategies with a column-factored (Woodbury)
    /// path — the valid `factored.core` values.
    pub fn column_factoring_keys(&self) -> Vec<&str> {
        self.decompositions
            .keys()
            .into_iter()
            .filter(|k| self.decompositions.get(k).is_some_and(|d| d.factors_columns()))
            .collect()
    }
}

impl Default for SolverRegistry {
    fn default() -> Self {
        Self::with_defaults()
    }
}

fn reject_strategy(ctx: &SolverBuildCtx<'_>) -> Result<(), String> {
    match &ctx.spec.strategy {
        Some(k) => Err(format!(
            "solver family '{}' has no decomposition axis (got '+{k}')",
            ctx.spec.family
        )),
        None => Ok(()),
    }
}

/// Fluent construction over a registry: schedules/dims/seed once, then
/// build any number of solvers by spec, optionally with the async refresh
/// pipeline attached.
pub struct SolverBuilder {
    registry: SolverRegistry,
    sched: KfacSchedules,
    dims: Vec<(usize, usize)>,
    seed: u64,
    pipeline: Option<PipelineConfig>,
}

impl SolverBuilder {
    /// Builder over [`SolverRegistry::with_defaults`] and the paper's §5
    /// schedules.
    pub fn new() -> Self {
        SolverBuilder {
            registry: SolverRegistry::with_defaults(),
            sched: KfacSchedules::paper(),
            dims: Vec::new(),
            seed: 0,
            pipeline: None,
        }
    }

    pub fn registry(mut self, registry: SolverRegistry) -> Self {
        self.registry = registry;
        self
    }

    pub fn schedules(mut self, sched: KfacSchedules) -> Self {
        self.sched = sched;
        self
    }

    pub fn dims(mut self, dims: &[(usize, usize)]) -> Self {
        self.dims = dims.to_vec();
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Attach this pipeline config (when `enabled`) to every built solver
    /// that supports a decomposition cadence.
    pub fn pipeline(mut self, cfg: PipelineConfig) -> Self {
        self.pipeline = Some(cfg);
        self
    }

    pub fn build(&self, name: &str) -> Result<Box<dyn Preconditioner>, String> {
        let mut solver = self.registry.build(name, self.sched.clone(), &self.dims, self.seed)?;
        if let Some(p) = &self.pipeline {
            // Online mode applies to the inline refresh path too, so it is
            // configured even when the async pipeline itself stays off.
            if p.online != crate::pipeline::OnlineMode::Off {
                solver.set_online(p.online, p.correction_every);
            }
            if p.enabled {
                solver.attach_pipeline(p);
            }
        }
        Ok(solver)
    }
}

impl Default for SolverBuilder {
    fn default() -> Self {
        Self::new()
    }
}

/// One-shot convenience over the default registry (the successor of the
/// old `Solver::by_name`).
pub fn build_solver(
    name: &str,
    sched: KfacSchedules,
    dims: &[(usize, usize)],
    seed: u64,
) -> Result<Box<dyn Preconditioner>, String> {
    SolverRegistry::with_defaults().build(name, sched, dims, seed)
}

/// The eleven solver names of the pre-registry API, all still resolvable.
pub const LEGACY_SOLVER_NAMES: [&str; 11] = [
    "kfac", "rs-kfac", "sre-kfac", "trunc-kfac", "nys-kfac", "ekfac", "rs-ekfac", "sre-ekfac",
    "nys-ekfac", "seng", "sgd",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parse_aliases_and_plus_syntax() {
        assert_eq!(
            SolverSpec::parse("rs-kfac").unwrap(),
            SolverSpec { family: "kfac".into(), strategy: Some("rsvd".into()) }
        );
        assert_eq!(SolverSpec::parse("rs-kfac").unwrap().canonical(), "kfac+rsvd");
        assert_eq!(
            SolverSpec::parse("ekfac+nystrom").unwrap(),
            SolverSpec { family: "ekfac".into(), strategy: Some("nystrom".into()) }
        );
        assert_eq!(
            SolverSpec::parse("seng").unwrap(),
            SolverSpec { family: "seng".into(), strategy: None }
        );
        // Unknown bare names become family-only specs (rejected at build).
        assert_eq!(SolverSpec::parse("adam").unwrap().family, "adam");
        assert!(SolverSpec::parse("kfac+").is_err());
        assert!(SolverSpec::parse("").is_err());
    }

    #[test]
    fn registry_builds_all_legacy_names() {
        let reg = SolverRegistry::with_defaults();
        let dims = [(8usize, 6usize)];
        for name in LEGACY_SOLVER_NAMES {
            let s = reg.build(name, KfacSchedules::paper(), &dims, 1).unwrap();
            assert_eq!(s.name(), name, "legacy name must round-trip");
        }
        assert!(reg.build("adam", KfacSchedules::paper(), &dims, 1).is_err());
        assert!(reg.build("kfac+adamantium", KfacSchedules::paper(), &dims, 1).is_err());
        assert!(reg.build("sgd+rsvd", KfacSchedules::paper(), &dims, 1).is_err());
    }

    #[test]
    fn canonical_specs_alias_legacy_names() {
        let reg = SolverRegistry::with_defaults();
        let dims = [(8usize, 6usize)];
        for (spec, legacy) in
            [("kfac+rsvd", "rs-kfac"), ("kfac+exact", "kfac"), ("ekfac+srevd", "sre-ekfac")]
        {
            let s = reg.build(spec, KfacSchedules::paper(), &dims, 1).unwrap();
            assert_eq!(s.name(), legacy, "{spec}");
        }
    }

    #[test]
    fn builder_fluent_construction() {
        let dims = [(8usize, 6usize)];
        let built = SolverBuilder::new()
            .schedules(KfacSchedules::paper())
            .dims(&dims)
            .seed(7)
            .pipeline(PipelineConfig { enabled: true, workers: 1, ..Default::default() })
            .build("rs-kfac")
            .unwrap();
        assert_eq!(built.name(), "rs-kfac");
        // Pipeline attached → diagnostics report it.
        assert!(built.diagnostics().pipeline.is_some());
        // SGD has no decomposition cadence: builds fine, no pipeline.
        let sgd = SolverBuilder::new().dims(&dims).build("sgd").unwrap();
        assert!(sgd.diagnostics().pipeline.is_none());
    }

    #[test]
    fn validate_spec_lists_known_specs_on_typo() {
        let reg = SolverRegistry::with_defaults();
        assert!(reg.validate_spec("kfac+rsvd").is_ok());
        assert!(reg.validate_spec("rs-ekfac").is_ok());
        assert!(reg.validate_spec("seng").is_ok());
        let err = reg.validate_spec("kfac+rsvdd").unwrap_err();
        assert!(err.contains("kfac+rsvd"), "error should list known specs: {err}");
        assert!(err.contains("unknown decomposition 'rsvdd'"), "{err}");
        let err = reg.validate_spec("adam").unwrap_err();
        assert!(err.contains("known specs"), "{err}");
        assert!(err.contains("seng"), "{err}");
        // Strategy suffixes on axis-less families fail up front, not at
        // build time (the sweep's fail-before-hours-of-runs contract).
        let err = reg.validate_spec("seng+rsvd").unwrap_err();
        assert!(err.contains("no decomposition axis"), "{err}");
        assert!(reg.validate_spec("sgd+exact").is_err());
        // Re-registering an axis-less family name clears the mark (the
        // replacement factory becomes the arbiter again).
        let mut reg2 = SolverRegistry::with_defaults();
        reg2.register_family("sgd", |ctx| {
            let _ = &ctx.strategy;
            Ok(Box::new(crate::optim::sgd::SgdOptimizer::new(
                crate::optim::sgd::SgdConfig::default(),
                ctx.dims.len(),
            )) as Box<dyn Preconditioner>)
        });
        assert!(reg2.validate_spec("sgd+rsvd").is_ok());
        // known_specs covers bare families + strategy expansions.
        let specs = reg.known_specs();
        assert!(specs.iter().any(|s| s == "ekfac+nystrom"));
        assert!(specs.iter().any(|s| s == "sgd"));
        assert!(!specs.iter().any(|s| s == "sgd+rsvd"));
    }

    /// Per-family *strategy* compatibility: `kfac+woodbury` is a valid
    /// spec, `ekfac+woodbury` is rejected up front with the reason (EK-FAC
    /// needs an explicit eigenbasis to rescale), and known_specs reflects
    /// the distinction.
    #[test]
    fn column_factored_strategies_respect_dense_only_families() {
        let reg = SolverRegistry::with_defaults();
        assert!(reg.validate_spec("kfac+woodbury").is_ok());
        assert!(reg.validate_spec("kfac+sketchcore").is_ok());
        let err = reg.validate_spec("ekfac+woodbury").unwrap_err();
        assert!(err.contains("cannot use column-factored strategy 'woodbury'"), "{err}");
        assert!(err.contains("no basis to rescale"), "{err}");
        let specs = reg.known_specs();
        assert!(specs.iter().any(|s| s == "kfac+woodbury"));
        assert!(specs.iter().any(|s| s == "kfac+sketchcore"));
        assert!(!specs.iter().any(|s| s == "ekfac+woodbury"));
        assert!(!specs.iter().any(|s| s == "ekfac+sketchcore"));
        // Build-time enforcement mirrors validate_spec.
        let dims = [(8usize, 6usize)];
        assert!(reg.build("ekfac+woodbury", KfacSchedules::paper(), &dims, 1).is_err());
        let built = reg.build("kfac+woodbury", KfacSchedules::paper(), &dims, 1).unwrap();
        assert_eq!(built.name(), "kfac+woodbury");
        // A column-factoring spec implies the policy: no pipeline, no
        // external dense factors, factored diagnostics ranks (0 columns
        // retained before the first update).
        assert!(!built.supports_external_factors());
        // An active policy routed onto a dense-only family errs with the
        // reason instead of silently training dense.
        let policy = FactoredPolicy {
            mode: crate::optim::preconditioner::FactoredMode::All,
            ..FactoredPolicy::default()
        };
        let err = reg
            .build_with_factored("ekfac+rsvd", KfacSchedules::paper(), &dims, 1, &policy)
            .unwrap_err();
        assert!(err.contains("requires dense factor state"), "{err}");
        // …and a policy with a bogus core cites the valid column-factoring
        // strategies.
        let bad = FactoredPolicy { core: "rsvd".into(), ..policy.clone() };
        let err = reg
            .build_with_factored("kfac+exact", KfacSchedules::paper(), &dims, 1, &bad)
            .unwrap_err();
        assert!(err.contains("dense decomposition"), "{err}");
        assert!(err.contains("woodbury"), "{err}");
        // The hybrid policy at an infinite threshold routes nothing — it
        // builds even for dense-only families (bitwise-legacy contract).
        let inert = FactoredPolicy {
            mode: crate::optim::preconditioner::FactoredMode::Hybrid,
            width_threshold: usize::MAX,
            ..FactoredPolicy::default()
        };
        assert!(reg
            .build_with_factored("ekfac+rsvd", KfacSchedules::paper(), &dims, 1, &inert)
            .is_ok());
    }

    #[test]
    fn registry_clones_share_factories() {
        let reg = SolverRegistry::with_defaults();
        let clone = reg.clone();
        let dims = [(8usize, 6usize)];
        let a = reg.build("kfac+rsvd", KfacSchedules::paper(), &dims, 1).unwrap();
        let b = clone.build("kfac+rsvd", KfacSchedules::paper(), &dims, 1).unwrap();
        assert_eq!(a.name(), b.name());
    }

    #[test]
    fn display_name_mapping() {
        assert_eq!(solver_display_name("kfac", "exact"), "kfac");
        assert_eq!(solver_display_name("kfac", "rsvd"), "rs-kfac");
        assert_eq!(solver_display_name("ekfac", "nystrom"), "nys-ekfac");
        assert_eq!(solver_display_name("kfac", "halfrank"), "kfac+halfrank");
    }
}
