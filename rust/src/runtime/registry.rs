//! Artifact registry: parses `artifacts/manifest.json` into typed specs.
//!
//! The manifest is written by `python/compile/aot.py` and is the single
//! source of truth for artifact signatures — the Rust side never re-derives
//! shapes from model configuration, it reads them here and validates every
//! call against them.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// Shape + dtype of one artifact input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Metadata for a model-kind artifact (parsed from the `meta` field).
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub widths: Vec<usize>,
    pub batch: usize,
    pub rho: f64,
}

impl ModelMeta {
    pub fn n_layers(&self) -> usize {
        self.widths.len() - 1
    }
}

/// One artifact: a lowered HLO-text module plus its full signature.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub path: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub kind: Option<String>,
    pub model_meta: Option<ModelMeta>,
}

/// The parsed manifest.
#[derive(Debug, Default)]
pub struct Registry {
    artifacts: BTreeMap<String, ArtifactSpec>,
    pub dir: PathBuf,
}

fn parse_tensor_spec(v: &Json) -> Result<TensorSpec> {
    let shape = v
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("spec missing shape"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = v
        .get("dtype")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("spec missing dtype"))?
        .to_string();
    Ok(TensorSpec { shape, dtype })
}

impl Registry {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Registry> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}; run `make artifacts` first", manifest_path.display()))?;
        let root = json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        let version = root.get("version").and_then(Json::as_usize).unwrap_or(0);
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let mut artifacts = BTreeMap::new();
        for row in root.get("artifacts").and_then(Json::as_arr).unwrap_or(&[]) {
            let name = row
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            let file = row
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact {name} missing file"))?;
            let inputs = row
                .get("inputs")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(parse_tensor_spec)
                .collect::<Result<Vec<_>>>()?;
            let outputs = row
                .get("outputs")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(parse_tensor_spec)
                .collect::<Result<Vec<_>>>()?;
            let kind = row
                .get("meta")
                .and_then(|m| m.get("kind"))
                .and_then(Json::as_str)
                .map(str::to_string);
            let model_meta = if kind.as_deref() == Some("model") {
                let meta = row.get("meta").unwrap();
                let widths = meta
                    .get("widths")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("model {name} missing widths"))?
                    .iter()
                    .map(|w| w.as_usize().ok_or_else(|| anyhow!("bad width")))
                    .collect::<Result<Vec<_>>>()?;
                let batch = meta
                    .get("batch")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("model {name} missing batch"))?;
                let rho = meta.get("rho").and_then(Json::as_f64).unwrap_or(0.95);
                Some(ModelMeta { widths, batch, rho })
            } else {
                None
            };
            let spec = ArtifactSpec { name: name.clone(), path: dir.join(file), inputs, outputs, kind, model_meta };
            artifacts.insert(name, spec);
        }
        Ok(Registry { artifacts, dir })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest (have: {:?})", self.names()))
    }

    pub fn names(&self) -> Vec<&str> {
        self.artifacts.keys().map(String::as_str).collect()
    }

    pub fn len(&self) -> usize {
        self.artifacts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.artifacts.is_empty()
    }

    /// All artifacts of a given meta-kind (e.g. "model").
    pub fn of_kind(&self, kind: &str) -> Vec<&ArtifactSpec> {
        self.artifacts.values().filter(|a| a.kind.as_deref() == Some(kind)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, body: &str) {
        let mut f = std::fs::File::create(dir.join("manifest.json")).unwrap();
        f.write_all(body.as_bytes()).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("rkfac_registry_test_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn loads_valid_manifest() {
        let d = tmpdir("ok");
        write_manifest(
            &d,
            r#"{"version": 1, "artifacts": [
                {"name": "mlp_step_t", "file": "mlp_step_t.hlo.txt",
                 "inputs": [{"shape": [32, 64], "dtype": "float32"}],
                 "outputs": [{"shape": [], "dtype": "float32"}],
                 "meta": {"kind": "model", "widths": [64, 32], "batch": 16, "rho": 0.95}}]}"#,
        );
        let reg = Registry::load(&d).unwrap();
        assert_eq!(reg.len(), 1);
        let a = reg.get("mlp_step_t").unwrap();
        assert_eq!(a.inputs[0].shape, vec![32, 64]);
        assert_eq!(a.outputs[0].shape, Vec::<usize>::new());
        let meta = a.model_meta.as_ref().unwrap();
        assert_eq!(meta.widths, vec![64, 32]);
        assert_eq!(meta.batch, 16);
        assert_eq!(reg.of_kind("model").len(), 1);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn missing_manifest_is_error_with_hint() {
        let d = tmpdir("missing");
        std::fs::remove_file(d.join("manifest.json")).ok();
        let err = Registry::load(&d).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn unknown_artifact_is_error() {
        let d = tmpdir("unknown");
        write_manifest(&d, r#"{"version": 1, "artifacts": []}"#);
        let reg = Registry::load(&d).unwrap();
        assert!(reg.get("nope").is_err());
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn bad_version_rejected() {
        let d = tmpdir("badver");
        write_manifest(&d, r#"{"version": 9, "artifacts": []}"#);
        assert!(Registry::load(&d).is_err());
        std::fs::remove_dir_all(&d).ok();
    }
}
