//! Host-side tensor: the marshaling type between the f64 `Matrix` world of
//! the coordinator and the f32 PJRT literals of the compiled artifacts.

use crate::linalg::Matrix;

/// A dense f32 host tensor with row-major layout and arbitrary rank
/// (rank 0 = scalar).
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "HostTensor: shape {:?} does not match data length {}",
            shape,
            data.len()
        );
        HostTensor { shape, data }
    }

    /// Scalar tensor.
    pub fn scalar(v: f32) -> Self {
        HostTensor { shape: vec![], data: vec![v] }
    }

    /// 1-D tensor.
    pub fn vec1(v: Vec<f32>) -> Self {
        HostTensor { shape: vec![v.len()], data: v }
    }

    /// Zero tensor of a given shape.
    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        HostTensor { shape, data: vec![0.0; n] }
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Scalar value (panics if not rank 0 / single element).
    pub fn as_scalar(&self) -> f32 {
        assert_eq!(self.data.len(), 1, "as_scalar on non-scalar tensor {:?}", self.shape);
        self.data[0]
    }

    /// Convert a 2-D tensor into an f64 [`Matrix`].
    pub fn to_matrix(&self) -> Matrix {
        assert_eq!(self.rank(), 2, "to_matrix: tensor rank {} != 2", self.rank());
        Matrix::from_f32(self.shape[0], self.shape[1], &self.data)
    }

    /// Build from an f64 [`Matrix`] (casts to f32).
    pub fn from_matrix(m: &Matrix) -> Self {
        HostTensor { shape: vec![m.rows(), m.cols()], data: m.to_f32() }
    }
}

impl From<&Matrix> for HostTensor {
    fn from(m: &Matrix) -> Self {
        HostTensor::from_matrix(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let t = HostTensor::scalar(3.5);
        assert_eq!(t.rank(), 0);
        assert_eq!(t.as_scalar(), 3.5);
    }

    #[test]
    fn matrix_roundtrip() {
        let m = Matrix::from_fn(3, 4, |i, j| (i * 4 + j) as f64);
        let t = HostTensor::from_matrix(&m);
        assert_eq!(t.shape, vec![3, 4]);
        let back = t.to_matrix();
        assert!(back.rel_err(&m) < 1e-7);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        HostTensor::new(vec![2, 3], vec![0.0; 5]);
    }
}
