//! PJRT execution engine: compile-once, execute-many.
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` → `HloModuleProto::
//! from_text_file` → `XlaComputation::from_proto` → `client.compile` —
//! then `execute` per call with [`HostTensor`] marshaling. Executables are
//! cached by artifact name; Python is never involved at runtime.

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::runtime::registry::{ArtifactSpec, Registry, TensorSpec};
use crate::runtime::tensor::HostTensor;

/// The runtime engine. One per process; interior mutability so trainers can
/// share it immutably while the executable cache fills lazily.
pub struct Engine {
    client: xla::PjRtClient,
    registry: Registry,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Create a CPU PJRT engine over the artifact directory.
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let registry = Registry::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, registry, cache: Mutex::new(HashMap::new()) })
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the executable for `name`.
    fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.registry.get(name)?;
        let proto = xla::HloModuleProto::from_text_file(&spec.path)
            .with_context(|| format!("parsing HLO text {}", spec.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client.compile(&comp).with_context(|| format!("compiling artifact '{name}'"))?,
        );
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Eagerly compile a set of artifacts (startup warm-up).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    fn check_inputs(spec: &ArtifactSpec, inputs: &[HostTensor]) -> Result<()> {
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact '{}': expected {} inputs, got {}",
                spec.name,
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(spec.inputs.iter()).enumerate() {
            if t.shape != s.shape {
                bail!(
                    "artifact '{}': input {i} shape {:?} != manifest {:?}",
                    spec.name,
                    t.shape,
                    s.shape
                );
            }
        }
        Ok(())
    }

    fn to_literal(t: &HostTensor) -> Result<xla::Literal> {
        if t.rank() == 0 {
            return Ok(xla::Literal::scalar(t.data[0]));
        }
        let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&t.data).reshape(&dims)?)
    }

    fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<HostTensor> {
        let data = lit.to_vec::<f32>()?;
        if data.len() != spec.element_count() {
            bail!("output element count {} != spec {:?}", data.len(), spec.shape);
        }
        Ok(HostTensor::new(spec.shape.clone(), data))
    }

    /// Execute artifact `name` with the given inputs; returns the outputs in
    /// manifest order. Shapes are validated against the manifest.
    pub fn execute(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let spec = self.registry.get(name)?.clone();
        Self::check_inputs(&spec, inputs)?;
        let exe = self.executable(name)?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(Self::to_literal).collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?;
        // Single-device execution: [replica 0][partition 0]; lowered with
        // return_tuple=True so the single output buffer is an N-tuple.
        let tuple = result[0][0].to_literal_sync()?.to_tuple()?;
        if tuple.len() != spec.outputs.len() {
            bail!(
                "artifact '{}': runtime returned {} outputs, manifest says {}",
                name,
                tuple.len(),
                spec.outputs.len()
            );
        }
        tuple
            .iter()
            .zip(spec.outputs.iter())
            .map(|(lit, s)| Self::from_literal(lit, s))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    // Engine tests that need real artifacts live in rust/tests/runtime_integration.rs
    // (they require `make artifacts` to have run). Here: pure marshaling units.
    use super::*;

    #[test]
    fn literal_roundtrip_matrix() {
        let t = HostTensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let lit = Engine::to_literal(&t).unwrap();
        let spec = TensorSpec { shape: vec![2, 3], dtype: "float32".into() };
        let back = Engine::from_literal(&lit, &spec).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_roundtrip_scalar() {
        let t = HostTensor::scalar(7.25);
        let lit = Engine::to_literal(&t).unwrap();
        let spec = TensorSpec { shape: vec![], dtype: "float32".into() };
        let back = Engine::from_literal(&lit, &spec).unwrap();
        assert_eq!(back.as_scalar(), 7.25);
    }
}
