//! Typed wrapper over the compiled model artifacts.
//!
//! Turns the flat tensor lists of `mlp_step_*` / `mlp_eval_*` / `mlp_sgd_*`
//! into the structured step the trainer wants, with `Matrix` (f64) at the
//! boundary — the coordinator does its optimizer math in f64, the model
//! compute runs in f32 inside PJRT.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::linalg::Matrix;
use crate::runtime::executor::Engine;
use crate::runtime::registry::ModelMeta;
use crate::runtime::tensor::HostTensor;

/// Output of one fused model step (Alg. 1's fwd+bwd+EA-factor update).
pub struct StepOutput {
    pub loss: f64,
    /// Per-layer weight gradients dL/dW_l.
    pub grads: Vec<Matrix>,
    /// Updated EA forward factors Ā^(l).
    pub a_factors: Vec<Matrix>,
    /// Updated EA backward factors Γ̄^(l).
    pub g_factors: Vec<Matrix>,
}

/// A model configuration compiled into step/eval/sgd artifacts.
pub struct CompiledModel {
    engine: Arc<Engine>,
    pub config: String,
    pub meta: ModelMeta,
}

impl CompiledModel {
    /// Look up the `mlp_step_<config>` family in the engine's registry.
    pub fn new(engine: Arc<Engine>, config: &str) -> Result<CompiledModel> {
        let spec = engine.registry().get(&format!("mlp_step_{config}"))?;
        let meta = match &spec.model_meta {
            Some(m) => m.clone(),
            None => bail!("artifact mlp_step_{config} has no model meta"),
        };
        Ok(CompiledModel { engine, config: config.to_string(), meta })
    }

    pub fn n_layers(&self) -> usize {
        self.meta.n_layers()
    }

    pub fn batch(&self) -> usize {
        self.meta.batch
    }

    pub fn widths(&self) -> &[usize] {
        &self.meta.widths
    }

    /// Expected weight shapes (d_out, d_in) per layer.
    pub fn weight_shapes(&self) -> Vec<(usize, usize)> {
        (0..self.n_layers()).map(|l| (self.meta.widths[l + 1], self.meta.widths[l])).collect()
    }

    /// He-style initial weights (seeded; mirrors python `init_params`).
    pub fn init_weights(&self, rng: &mut crate::linalg::Pcg64) -> Vec<Matrix> {
        self.weight_shapes()
            .iter()
            .map(|&(dout, din)| {
                let scale = (2.0 / din as f64).sqrt();
                Matrix::from_fn(dout, din, |_, _| scale * rng.gaussian())
            })
            .collect()
    }

    /// Identity-initialized EA factors (Alg. 1: Ā₋₁ = Γ̄₋₁ = I).
    pub fn init_factors(&self) -> (Vec<Matrix>, Vec<Matrix>) {
        let n = self.n_layers();
        let a = (0..n).map(|l| Matrix::eye(self.meta.widths[l])).collect();
        let g = (0..n).map(|l| Matrix::eye(self.meta.widths[l + 1])).collect();
        (a, g)
    }

    fn pack(mats: &[&Matrix]) -> Vec<HostTensor> {
        mats.iter().map(|m| HostTensor::from_matrix(m)).collect()
    }

    /// Fused training-step compute: loss, per-layer grads, EA factor updates.
    ///
    /// `x`: (d0, B) batch; `y`: (C, B) one-hot labels.
    pub fn step(
        &self,
        ws: &[Matrix],
        a_factors: &[Matrix],
        g_factors: &[Matrix],
        x: &Matrix,
        y: &Matrix,
    ) -> Result<StepOutput> {
        let n = self.n_layers();
        if ws.len() != n || a_factors.len() != n || g_factors.len() != n {
            bail!("step: expected {n} layers");
        }
        let mut inputs: Vec<HostTensor> = Vec::with_capacity(3 * n + 2);
        inputs.extend(Self::pack(&ws.iter().collect::<Vec<_>>()));
        inputs.extend(Self::pack(&a_factors.iter().collect::<Vec<_>>()));
        inputs.extend(Self::pack(&g_factors.iter().collect::<Vec<_>>()));
        inputs.push(HostTensor::from_matrix(x));
        inputs.push(HostTensor::from_matrix(y));
        let out = self.engine.execute(&format!("mlp_step_{}", self.config), &inputs)?;
        if out.len() != 1 + 3 * n {
            bail!("step: expected {} outputs, got {}", 1 + 3 * n, out.len());
        }
        let loss = out[0].as_scalar() as f64;
        let grads = out[1..1 + n].iter().map(HostTensor::to_matrix).collect();
        let a_new = out[1 + n..1 + 2 * n].iter().map(HostTensor::to_matrix).collect();
        let g_new = out[1 + 2 * n..1 + 3 * n].iter().map(HostTensor::to_matrix).collect();
        Ok(StepOutput { loss, grads, a_factors: a_new, g_factors: g_new })
    }

    /// Evaluation pass: (mean loss, #correct) on one batch.
    pub fn eval(&self, ws: &[Matrix], x: &Matrix, y: &Matrix) -> Result<(f64, usize)> {
        let mut inputs = Self::pack(&ws.iter().collect::<Vec<_>>());
        inputs.push(HostTensor::from_matrix(x));
        inputs.push(HostTensor::from_matrix(y));
        let out = self.engine.execute(&format!("mlp_eval_{}", self.config), &inputs)?;
        Ok((out[0].as_scalar() as f64, out[1].as_scalar() as usize))
    }

    /// Fused SGD step (baseline): returns (loss, updated weights).
    pub fn sgd(&self, ws: &[Matrix], x: &Matrix, y: &Matrix) -> Result<(f64, Vec<Matrix>)> {
        let mut inputs = Self::pack(&ws.iter().collect::<Vec<_>>());
        inputs.push(HostTensor::from_matrix(x));
        inputs.push(HostTensor::from_matrix(y));
        let out = self.engine.execute(&format!("mlp_sgd_{}", self.config), &inputs)?;
        let loss = out[0].as_scalar() as f64;
        let ws_new = out[1..].iter().map(HostTensor::to_matrix).collect();
        Ok((loss, ws_new))
    }
}
