//! Runtime — PJRT execution of the AOT-compiled artifacts.
//!
//! `Engine` owns the PJRT CPU client and an executable cache keyed by
//! artifact name; `Registry` is the parsed `manifest.json`; `CompiledModel`
//! is the typed facade the trainer drives. Python never runs here: the
//! artifacts are HLO text produced once by `make artifacts`.

pub mod executor;
pub mod model;
pub mod registry;
pub mod tensor;

pub use executor::Engine;
pub use model::{CompiledModel, StepOutput};
pub use registry::{ArtifactSpec, ModelMeta, Registry, TensorSpec};
pub use tensor::HostTensor;
