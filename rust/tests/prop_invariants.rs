//! Property-based invariants over the coordinator + NLA stack
//! (util::prop — the in-repo proptest stand-in; seeds printed on failure).

use std::sync::Arc;

use rkfac::coordinator::metrics::{mean_std, summarize, EpochRecord, RunResult};
use rkfac::data::{Batcher, Dataset};
use rkfac::linalg::backend::{self, BackendKind, Precision};
use rkfac::linalg::{chol, evd, gemm, qr, svd, Matrix, Pcg64};
use rkfac::nn::models;
use rkfac::optim::kfac::KfacOptimizer;
use rkfac::optim::schedules::{KfacSchedules, StepSchedule};
use rkfac::rnla::{decomposition, errors, rsvd, srevd, LowRankFactor, SketchConfig};
use rkfac::util::prop::{check, default_cases, ensure, ensure_close, Gen};

fn cases() -> usize {
    default_cases()
}

// ---------------------------------------------------------------------------
// NLA invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_qr_reconstruction_and_orthogonality() {
    check("qr", cases(), |g: &mut Gen<'_>| {
        let m = g.usize_in(2, 30);
        let n = g.usize_in(1, m);
        let a = g.matrix(m, n);
        let f = qr::thin_qr(&a);
        ensure(gemm::matmul(&f.q, &f.r).rel_err(&a) < 1e-9, "QR != A")?;
        ensure(qr::orthogonality_defect(&f.q) < 1e-9, "Q not orthonormal")
    });
}

#[test]
fn prop_evd_eigen_relation() {
    check("evd", cases(), |g: &mut Gen<'_>| {
        let n = g.usize_in(2, 24);
        let decay = g.f64_in(0.3, 0.95);
        let x = g.decaying_psd(n, decay);
        let e = evd::sym_evd(&x);
        ensure(e.reconstruct().rel_err(&x) < 1e-8, "EVD reconstruct")?;
        for w in e.lambda.windows(2) {
            ensure(w[0] >= w[1] - 1e-12, "descending")?;
        }
        Ok(())
    });
}

#[test]
fn prop_svd_eckart_young_optimality() {
    // RSVD error must be within a modest factor of the optimal rank-r error.
    check("eckart-young", cases() / 2, |g: &mut Gen<'_>| {
        let n = g.usize_in(8, 28);
        let x = g.decaying_psd(n, 0.6);
        let r = g.usize_in(2, n / 2);
        let exact = svd::thin_svd(&x);
        let optimal: f64 = exact.sigma[r..].iter().map(|s| s * s).sum::<f64>().sqrt();
        let out = rsvd(&x, &SketchConfig::new(r, 5, 2), g.rng);
        let err = (&x - &out.reconstruct_vv()).fro_norm();
        ensure(err <= 2.0 * optimal + 1e-9, format!("rsvd err {err} vs optimal {optimal}"))
    });
}

#[test]
fn prop_eq13_matches_dense_inverse() {
    check("eq13", cases() / 2, |g: &mut Gen<'_>| {
        let n = g.usize_in(3, 18);
        let x = g.decaying_psd(n, 0.5);
        let e = evd::sym_evd(&x);
        let r = g.usize_in(1, n);
        let f = LowRankFactor::new(e.u.first_cols(r), e.lambda[..r].to_vec());
        let lambda = g.f64_in(0.05, 1.5);
        let cols = g.usize_in(1, 4);
        let v = g.matrix(n, cols);
        let got = f.damped_inverse_apply(lambda, &v);
        let mut dense = f.reconstruct();
        dense.add_diag(lambda);
        let expect = chol::spd_solve(&dense, &v).map_err(|e| e.to_string())?;
        ensure(got.rel_err(&expect) < 1e-7, format!("eq13 err {}", got.rel_err(&expect)))
    });
}

#[test]
fn prop_srevd_eigenvalues_below_exact() {
    // Rayleigh–Ritz: projected eigenvalues never exceed the true ones.
    check("rayleigh-ritz", cases() / 2, |g: &mut Gen<'_>| {
        let n = g.usize_in(6, 24);
        let x = g.decaying_psd(n, 0.7);
        let exact = evd::sym_evd(&x);
        let r = g.usize_in(2, n / 2);
        let out = srevd(&x, &SketchConfig::new(r, 3, 1), g.rng);
        for (i, l) in out.lambda.iter().enumerate() {
            ensure(*l <= exact.lambda[i] + 1e-8, format!("λ̃_{i} {l} > λ_{i}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_prop31_bound_holds_on_ea_streams() {
    // The paper's Proposition 3.1, checked on simulated EA gram streams.
    check("prop31", 8, |g: &mut Gen<'_>| {
        let d = g.usize_in(16, 48);
        let n = g.usize_in(2, 6);
        let rho = g.f64_in(0.4, 0.9);
        let steps = g.usize_in(50, 150);
        let mut m_bar = Matrix::eye(d);
        let mut sigma_max2: f64 = 1.0; // identity init ~ σ² floor of 1
        for _ in 0..steps {
            let m = g.matrix(d, n);
            let smax = svd::spectral_norm_est(&m, 15, 7);
            sigma_max2 = sigma_max2.max(smax * smax / n as f64);
            gemm::ea_gram_update(&mut m_bar, rho, &m, n as f64);
        }
        let e = evd::sym_evd(&m_bar);
        let eps = 0.05;
        let alpha = (e.lambda[0] / sigma_max2).min(0.99);
        if alpha <= 0.01 {
            return Ok(()); // assumption of Prop 3.1 not met; skip
        }
        let bound = errors::prop31_mode_bound(alpha, eps, rho, n, d);
        let empirical = errors::modes_above(&e.lambda, eps);
        ensure(empirical <= bound, format!("Prop3.1 violated: {empirical} > {bound}"))
    });
}

// ---------------------------------------------------------------------------
// Coordinator invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_batcher_partitions_each_epoch() {
    check("batcher", cases(), |g: &mut Gen<'_>| {
        let n = g.usize_in(4, 200);
        let b = g.usize_in(1, n);
        let mut seen = vec![0usize; n];
        for batch in Batcher::new(n, b, g.rng) {
            ensure(batch.len() == b, "batch size")?;
            for i in batch {
                seen[i] += 1;
            }
        }
        ensure(seen.iter().all(|&c| c <= 1), "duplicate sample in epoch")?;
        let covered = seen.iter().filter(|&&c| c == 1).count();
        ensure(covered == (n / b) * b, "wrong coverage")
    });
}

#[test]
fn prop_dataset_normalization_stats() {
    check("normalize", cases(), |g: &mut Gen<'_>| {
        let d = g.usize_in(2, 12);
        let n = g.usize_in(4, 40);
        let x = g.matrix(d, n);
        let labels = g.labels(n, 3);
        let mut ds = Dataset::new(x, labels, 3);
        ds.normalize();
        for r in 0..d {
            let row = ds.x.row(r);
            let mean = row.iter().sum::<f64>() / n as f64;
            let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n as f64;
            ensure(mean.abs() < 1e-9, "mean != 0")?;
            ensure((var - 1.0).abs() < 1e-6 || var < 1e-12, "var != 1")?;
        }
        Ok(())
    });
}

#[test]
fn prop_kfac_step_linear_in_gradient_scale() {
    // The preconditioner is fixed given factors: step(c·g) = c·step(g).
    check("kfac-linearity", cases() / 2, |g: &mut Gen<'_>| {
        let da = g.usize_in(4, 12);
        let dg = g.usize_in(3, 10);
        let sched = KfacSchedules {
            rho: 0.9,
            t_ku: 1,
            t_ki: StepSchedule::constant(1.0),
            lambda: StepSchedule::constant(g.f64_in(0.05, 0.5)),
            alpha: StepSchedule::constant(1.0),
            rank: StepSchedule::constant(da.min(dg) as f64),
            oversample: StepSchedule::constant(3.0),
            n_power_iter: 1,
            weight_decay: 0.0,
        };
        let dims = [(da, dg)];
        let a = vec![g.decaying_psd(da, 0.7)];
        let gm = vec![g.decaying_psd(dg, 0.7)];
        let grad = g.matrix(dg, da);
        let c = g.f64_in(0.1, 5.0);
        let scaled = &grad * c;
        let mut o1 = KfacOptimizer::new(Arc::new(decomposition::Rsvd), sched.clone(), &dims, 5);
        let mut o2 = KfacOptimizer::new(Arc::new(decomposition::Rsvd), sched, &dims, 5);
        let s1 = o1.step_with_factors(0, a.clone(), gm.clone(), &[&grad]).remove(0);
        let s2 = o2.step_with_factors(0, a, gm, &[&scaled]).remove(0);
        let s1c = &s1 * c;
        ensure(s2.rel_err(&s1c) < 1e-6, format!("not linear: {}", s2.rel_err(&s1c)))
    });
}

#[test]
fn prop_apply_steps_weight_decay_shrinks_norm() {
    check("weight-decay", cases() / 2, |g: &mut Gen<'_>| {
        let mut net = models::mlp(&[6, 5, 10], 3);
        let x = g.matrix(6, 4);
        let labels = g.labels(4, 10);
        net.train_batch(&x, &labels, true);
        let before: f64 = net.state_vector().iter().map(|v| v * v).sum();
        // zero deltas + weight decay must strictly shrink weights
        let zeros: Vec<Matrix> =
            net.kfac_dims().iter().map(|&(a, gdim)| Matrix::zeros(gdim, a)).collect();
        net.apply_steps(&zeros, 0.1, 0.5);
        let after: f64 = net
            .state_vector()
            .iter()
            .map(|v| v * v)
            .sum();
        ensure(after < before, format!("norm grew: {before} -> {after}"))
    });
}

#[test]
fn prop_summary_statistics_consistent() {
    check("summary", cases(), |g: &mut Gen<'_>| {
        let n_runs = g.usize_in(1, 5);
        let epochs = g.usize_in(1, 8);
        let runs: Vec<RunResult> = (0..n_runs)
            .map(|seed| {
                let records: Vec<EpochRecord> = (0..epochs)
                    .map(|e| EpochRecord {
                        epoch: e,
                        wall_s: (e + 1) as f64,
                        train_loss: 1.0,
                        test_loss: 1.0,
                        test_acc: g.f64_in(0.0, 1.0),
                        decomp_s: 0.0,
                    })
                    .collect();
                RunResult {
                    solver: "x".into(),
                    seed: seed as u64,
                    records,
                    total_s: epochs as f64,
                    rank_trace: vec![],
                    pipe_trace: vec![],
                }
            })
            .collect();
        let target = g.f64_in(0.0, 1.0);
        let s = summarize(&runs, &[target]);
        let hits = s.time_to[0].3;
        let manual = runs.iter().filter(|r| r.best_acc() >= target).count();
        ensure(hits == manual, format!("hits {hits} != manual {manual}"))?;
        // mean_std on constant data is (c, 0)
        let (m, sd) = mean_std(&vec![2.5; g.usize_in(2, 6)]);
        ensure_close(m, 2.5, 1e-12, "mean")?;
        ensure(sd.abs() < 1e-12, "std of constant")
    });
}

#[test]
fn prop_woodbury_matches_dense() {
    check("woodbury", cases() / 2, |g: &mut Gen<'_>| {
        let d = g.usize_in(4, 20);
        let k = g.usize_in(1, d.min(6));
        let u = g.matrix(d, k);
        let lambda = g.f64_in(0.1, 2.0);
        let nscale = g.usize_in(1, 16) as f64;
        let b = g.matrix(d, 2);
        let got = chol::woodbury_solve(&u, nscale, lambda, &b).map_err(|e| e.to_string())?;
        let mut dense = gemm::matmul_nt(&u, &u);
        dense.scale_inplace(1.0 / nscale);
        dense.add_diag(lambda);
        let expect = chol::spd_solve(&dense, &b).map_err(|e| e.to_string())?;
        ensure(got.rel_err(&expect) < 1e-7, format!("woodbury err {}", got.rel_err(&expect)))
    });
}

#[test]
fn prop_mixed_precision_sketch_gemms_within_f32_tolerance() {
    // Mixed precision (f32 storage, f64 accumulation) is only ever a
    // tolerance claim, never a bitwise one: each operand demotion costs a
    // relative ~2^-24, so the product must land within f32 roundoff of the
    // pinned-f64 kernels across random shapes.
    check("mixed-gemm-tol", cases(), |g: &mut Gen<'_>| {
        let m = g.usize_in(2, 40);
        let k = g.usize_in(2, 40);
        let n = g.usize_in(1, 24);
        let a = g.matrix(m, k);
        let b = g.matrix(k, n);
        let p = g.matrix(k, m);
        // Pin the baseline under an explicit f64 scope so a concurrently
        // running mixed-precision test cannot leak its selection in here.
        let (exact, exact_tn) = {
            let _bk = backend::scoped(BackendKind::Reference, 1, Precision::F64);
            (gemm::matmul(&a, &b), gemm::matmul_tn(&p, &b))
        };
        let (mixed, mixed_tn) = {
            let _bk = backend::scoped(BackendKind::Threaded, 4, Precision::Mixed);
            (backend::sketch_matmul(&a, &b), backend::sketch_matmul_tn(&p, &b))
        };
        let err = mixed.rel_err(&exact);
        ensure(err < 1e-5, format!("mixed matmul {m}x{k}x{n}: rel err {err:e}"))?;
        let err_tn = mixed_tn.rel_err(&exact_tn);
        ensure(err_tn < 1e-5, format!("mixed matmul_tn {m}x{k}x{n}: rel err {err_tn:e}"))
    });
}

#[test]
fn prop_mixed_precision_rsvd_reconstruction_close_to_f64() {
    // End-to-end through the range finder: the same-seed mixed-precision
    // RSVD must approximate X essentially as well as the f64 one — the
    // sketch's own randomness dominates the f32 demotion noise (the
    // paper's §4 argument for cheap sketching precision).
    check("mixed-rsvd-recon", cases() / 2, |g: &mut Gen<'_>| {
        let d = g.usize_in(16, 48);
        let decay = g.f64_in(0.55, 0.9);
        let x = g.decaying_psd(d, decay);
        let rank = g.usize_in(2, 6);
        let cfg = SketchConfig::new(rank, 4, 2);
        let seed = g.rng.next_u64();
        let recon_err = |fac: &rsvd::Rsvd| {
            let mut us = fac.u.clone();
            gemm::scale_cols(&mut us, &fac.sigma);
            let mut diff = gemm::matmul_nt(&us, &fac.v);
            diff.axpy(-1.0, &x);
            diff.fro_norm()
        };
        let f64_err = {
            let _bk = backend::scoped(BackendKind::Reference, 1, Precision::F64);
            recon_err(&rsvd::rsvd(&x, &cfg, &mut Pcg64::new(seed)))
        };
        let mixed_err = {
            let _bk = backend::scoped(BackendKind::Threaded, 3, Precision::Mixed);
            recon_err(&rsvd::rsvd(&x, &cfg, &mut Pcg64::new(seed)))
        };
        ensure(
            mixed_err <= f64_err + 1e-4 * x.fro_norm().max(1.0),
            format!("mixed rsvd d={d} r={rank}: err {mixed_err:e} vs f64 {f64_err:e}"),
        )
    });
}
