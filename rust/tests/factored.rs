//! Factored-solve subsystem contract suite.
//!
//! The Woodbury path's promises, pinned end to end:
//!
//! * **Exactness while the window holds** — `kfac+woodbury` preconditions
//!   with the same damped inverse as the dense exact engine (the
//!   retained-column representation of the EA recursion is lossless until
//!   `max_cols` trims), so their step deltas agree to solver tolerance.
//! * **Bitwise-off** — a hybrid policy whose threshold routes nothing is
//!   byte-identical to the legacy engine: same deltas, same KF01
//!   checkpoint bytes.
//! * **No dense G** — a factored block never allocates its o×o gram,
//!   asserted through the obs counters rather than by inspection.
//! * **KF02 round-trip** — a factored engine checkpoint restores bitwise
//!   and the continuation reproduces the uninterrupted trajectory.
//! * **Session wiring** — `[factored]` routes through
//!   `SolverRegistry::build_with_factored` and a wide-head training run
//!   completes under `mode = "all"`.
//!
//! The obs gate and buffers are process-wide; tests touching them
//! serialize on one lock (this integration binary is its own process).

use std::sync::{Arc, Mutex, MutexGuard};

use rkfac::coordinator::{
    DataChoice, EngineChoice, FactoredConfig, ModelChoice, Session, TrainConfig,
};
use rkfac::linalg::{Matrix, Pcg64};
use rkfac::nn::models;
use rkfac::obs;
use rkfac::optim::schedules::{KfacSchedules, StepSchedule};
use rkfac::optim::{
    build_solver, FactoredMode, FactoredPolicy, KfacOptimizer, Preconditioner, SolverRegistry,
};
use rkfac::rnla::decomposition::Exact;
use rkfac::rnla::Woodbury;

static OBS_LOCK: Mutex<()> = Mutex::new(());

fn obs_lock() -> MutexGuard<'static, ()> {
    OBS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Fast deterministic schedules (constant λ/α, refresh every other step).
fn sched() -> KfacSchedules {
    KfacSchedules {
        rho: 0.9,
        t_ku: 1,
        t_ki: StepSchedule::constant(2.0),
        lambda: StepSchedule::constant(0.1),
        alpha: StepSchedule::constant(0.2),
        rank: StepSchedule::constant(6.0),
        oversample: StepSchedule::constant(4.0),
        n_power_iter: 2,
        weight_decay: 0.0,
    }
}

fn all_policy() -> FactoredPolicy {
    FactoredPolicy { mode: FactoredMode::All, ..FactoredPolicy::default() }
}

/// Drive two solvers over the same trajectory; compare per-step deltas
/// with `cmp` (rel-err tolerance or bitwise, per test).
fn run_pair(
    a: &mut dyn Preconditioner,
    b: &mut dyn Preconditioner,
    widths: &[usize],
    rounds: usize,
    mut cmp: impl FnMut(usize, usize, &Matrix, &Matrix),
) {
    let mut net = models::mlp(widths, 77);
    let mut rng = Pcg64::new(78);
    let classes = *widths.last().unwrap();
    for round in 0..rounds {
        let x = rng.gaussian_matrix(widths[0], 8);
        let labels: Vec<usize> = (0..8).map(|i| i % classes).collect();
        net.train_batch(&x, &labels, true);
        let caps = net.kfac_captures();
        let da = a.step(0, &caps);
        let db = b.step(0, &caps);
        assert_eq!(da.len(), db.len());
        for (bi, (x1, x2)) in da.iter().zip(db.iter()).enumerate() {
            cmp(round, bi, x1, x2);
        }
        let (lr, wd) = a.lr_wd(0);
        net.apply_steps(&da, lr, wd);
    }
}

/// `kfac+woodbury` ≡ dense exact K-FAC while the retained-column window
/// never trims: the factored representation of the EA recursion is exact,
/// so the only divergence is solve arithmetic (Woodbury vs full EVD).
#[test]
fn woodbury_matches_dense_exact_engine_while_window_holds() {
    let registry = SolverRegistry::with_defaults();
    let dims = [(12usize, 8usize), (8, 10)];
    // 5 rounds × 8 columns = 40 ≤ max_cols: lossless window.
    let mut dense = build_solver("kfac", sched(), &dims, 5).unwrap();
    let mut fact = registry
        .build_with_factored("kfac+woodbury", sched(), &dims, 5, &FactoredPolicy::default())
        .unwrap();
    assert_eq!(fact.name(), "kfac+woodbury");
    run_pair(dense.as_mut(), fact.as_mut(), &[12, 8, 10], 5, |round, bi, x1, x2| {
        let err = x1.rel_err(x2);
        assert!(err < 1e-8, "round {round} block {bi}: rel err {err}");
    });
}

/// A hybrid policy that routes nothing is the legacy engine, bitwise:
/// identical step deltas and identical KF01 checkpoint bytes.
#[test]
fn hybrid_at_infinite_threshold_is_bitwise_legacy() {
    let dims = [(12usize, 8usize), (8, 10)];
    let inert = FactoredPolicy {
        mode: FactoredMode::Hybrid,
        width_threshold: usize::MAX,
        ..FactoredPolicy::default()
    };
    assert!(inert.is_off());
    let mut legacy = KfacOptimizer::new(Arc::new(Exact), sched(), &dims, 5);
    let mut hybrid =
        KfacOptimizer::with_policy(Arc::new(Exact), None, sched(), &dims, 5, inert.clone())
            .unwrap();
    assert!(!hybrid.has_factored_blocks());
    run_pair(&mut legacy, &mut hybrid, &[12, 8, 10], 3, |round, bi, x1, x2| {
        assert_eq!(x1.as_slice(), x2.as_slice(), "round {round} block {bi} deltas differ");
    });
    // Same bytes, same KF01 tag: dense checkpoints are unchanged with the
    // subsystem compiled in but off.
    let a = legacy.save_state_bytes();
    let b = hybrid.save_state_bytes();
    assert_eq!(a, b, "inert policy must not perturb checkpoint bytes");
    assert_eq!(&a[..4], b"KF01");
    // The registry path accepts the inert policy on any solver family.
    let registry = SolverRegistry::with_defaults();
    assert!(registry.build_with_factored("ekfac+rsvd", sched(), &dims, 5, &inert).is_ok());
}

/// A factored block's o×o gram is never allocated — pinned through the
/// construction counters (`kfac.dense_g_alloc` / `kfac.factored_g_block`)
/// and the `factored.*` spans a training step emits.
#[test]
fn factored_blocks_never_allocate_dense_g() {
    let _g = obs_lock();
    obs::set_enabled(true);
    obs::reset();
    let dims = [(12usize, 8usize), (8, 2000)];
    let policy = FactoredPolicy {
        mode: FactoredMode::Hybrid,
        width_threshold: 1000,
        ..FactoredPolicy::default()
    };
    let mut solver = KfacOptimizer::with_policy(
        Arc::new(Exact),
        Some(Arc::new(Woodbury)),
        sched(),
        &dims,
        5,
        policy,
    )
    .unwrap();
    assert!(solver.has_factored_blocks());
    let mut net = models::mlp(&[12, 8, 2000], 77);
    let mut rng = Pcg64::new(78);
    let x = rng.gaussian_matrix(12, 8);
    let labels: Vec<usize> = (0..8).map(|i| i % 2000).collect();
    net.train_batch(&x, &labels, true);
    let caps = net.kfac_captures();
    let deltas = solver.step(0, &caps);
    assert!(deltas.iter().all(|d| d.all_finite()));
    obs::set_enabled(false);
    let snap = obs::take_snapshot();
    // Exactly one block each way: the 8-wide G stays dense, the 2000-wide
    // G is factored — and no second dense allocation ever happened.
    assert_eq!(
        snap.metrics.get("kfac.dense_g_alloc"),
        Some(&obs::Metric::Counter(1)),
        "only the narrow block may allocate a dense G"
    );
    assert_eq!(snap.metrics.get("kfac.factored_g_block"), Some(&obs::Metric::Counter(1)));
    let names: Vec<&str> = snap.events.iter().map(|e| e.name.as_str()).collect();
    assert!(names.contains(&"factored.core_chol"), "refresh must re-Cholesky the core");
    assert!(names.contains(&"factored.apply"), "precondition must route through the solve");
}

/// KF02 save/load: the factored engine restores bitwise and the resumed
/// trajectory reproduces the uninterrupted one exactly.
#[test]
fn kf02_checkpoint_roundtrip_is_bitwise() {
    let dims = [(12usize, 8usize), (8, 10)];
    let registry = SolverRegistry::with_defaults();
    let build = || {
        registry
            .build_with_factored("kfac+woodbury", sched(), &dims, 5, &FactoredPolicy::default())
            .unwrap()
    };
    let mut a = build();
    let mut net = models::mlp(&[12, 8, 10], 77);
    let mut rng = Pcg64::new(78);
    let mut batch = |rng: &mut Pcg64, net: &mut rkfac::nn::Network| {
        let x = rng.gaussian_matrix(12, 8);
        let labels: Vec<usize> = (0..8).map(|i| i % 10).collect();
        net.train_batch(&x, &labels, true);
    };
    for _ in 0..3 {
        batch(&mut rng, &mut net);
        let caps = net.kfac_captures();
        let d = a.step(0, &caps);
        let (lr, wd) = a.lr_wd(0);
        net.apply_steps(&d, lr, wd);
    }
    let bytes = a.save_state().expect("kfac engine checkpoints");
    assert_eq!(&bytes[..4], b"KF02", "factored engines write the v2 layout");
    let mut b = build();
    b.load_state(&bytes).unwrap();
    assert_eq!(b.save_state().unwrap(), bytes, "restore must be bitwise");
    // Continue both from the same point: bitwise-equal deltas.
    for round in 0..2 {
        batch(&mut rng, &mut net);
        let caps = net.kfac_captures();
        let da = a.step(0, &caps);
        let db = b.step(0, &caps);
        for (bi, (x1, x2)) in da.iter().zip(db.iter()).enumerate() {
            assert_eq!(x1.as_slice(), x2.as_slice(), "round {round} block {bi}");
        }
        let (lr, wd) = a.lr_wd(0);
        net.apply_steps(&da, lr, wd);
    }
    // A dense-config engine refuses the factored checkpoint (and vice
    // versa): kind-vs-config mismatch, not silent reinterpretation.
    let mut dense = build_solver("kfac", sched(), &dims, 5).unwrap();
    assert!(dense.load_state(&bytes).is_err());
}

/// The session/config wiring end to end: a wide-head run under
/// `[factored] mode = "all"` trains to completion on the native engine,
/// and the pipeline combination is refused at wiring time.
#[test]
fn session_trains_wide_head_with_factored_policy() {
    let mut cfg = TrainConfig {
        solver: "kfac".into(),
        epochs: 2,
        batch: 16,
        seed: 3,
        model: ModelChoice::Mlp { widths: vec![48, 16, 600] },
        data: DataChoice::Synthetic { n_train: 64, n_test: 32, height: 4, width: 4, channels: 3 },
        engine: EngineChoice::Native,
        targets: vec![0.5],
        augment: false,
        out_dir: std::env::temp_dir()
            .join(format!("rkfac_factored_{}", std::process::id()))
            .to_string_lossy()
            .into_owned(),
        sched_width: 48,
        factored: FactoredConfig { mode: "all".into(), ..FactoredConfig::default() },
        ..Default::default()
    };
    let result = Session::new(cfg.clone()).run().unwrap();
    assert_eq!(result.records.len(), 2);
    assert!(result.records.iter().all(|r| r.train_loss.is_finite()));
    // Same run, pipeline on: refused with the inline-only rationale.
    cfg.pipeline.enabled = true;
    let err = Session::new(cfg).run().unwrap_err().to_string();
    assert!(err.contains("inline-only"), "{err}");
}
