//! Contract tests for online (incremental) decomposition refreshes —
//! `[pipeline] online` and the [`rkfac::rnla::Decomposition::update`] hook.
//!
//! 1. **Decline fallback is invisible** — a strategy that advertises
//!    update support but declines every attempt trains bitwise like the
//!    plain recompute engine (same per-(round, block, side) RNG streams).
//! 2. **Off is off** — `set_online(Off, ..)` leaves steps *and* the
//!    checkpoint byte stream identical to an engine that never heard of
//!    online mode (golden-suite stability).
//! 3. **Error envelope** — on a decayed-spectrum PSD factor, the rotated
//!    basis tracks a fresh RSVD of the densely-updated matrix within a
//!    small multiple of the fresh sketch's own error.
//! 4. **Checkpoint round-trip** — incremental-basis state (pending
//!    composed deltas + counters) survives save/load bitwise: the resumed
//!    run reproduces the uninterrupted one step for step.
//! 5. **`Decomposition::tune` interaction** — the update path truncates to
//!    the tuned rank, exactly like a fresh decomposition would.
//! 6. **The point of the feature** — with `online = rsvd`, full
//!    decompositions per epoch drop to the correction cadence; the new
//!    update-vs-full counters prove it.

use std::sync::Arc;

use rkfac::linalg::{gemm, Matrix, Pcg64};
use rkfac::nn::{models, Network};
use rkfac::optim::schedules::{KfacSchedules, StepSchedule};
use rkfac::optim::KfacOptimizer;
use rkfac::pipeline::OnlineMode;
use rkfac::rnla::{
    decomposition, DecompMeta, Decomposition, LowRankFactor, SketchConfig, UpdateOutcome,
};

fn sched(rank: usize, t_ki: usize) -> KfacSchedules {
    KfacSchedules {
        rho: 0.9,
        t_ku: 1,
        t_ki: StepSchedule::constant(t_ki as f64),
        lambda: StepSchedule::constant(0.1),
        alpha: StepSchedule::constant(0.1),
        rank: StepSchedule::constant(rank as f64),
        oversample: StepSchedule::constant(4.0),
        n_power_iter: 1,
        weight_decay: 0.0,
    }
}

/// Drive `steps` native-engine steps on deterministic synthetic data,
/// returning every weight delta produced (flattened for comparison).
fn run_native(
    opt: &mut KfacOptimizer,
    net: &mut Network,
    widths: &[usize],
    steps: usize,
    data_seed: u64,
) -> Vec<Vec<f64>> {
    let mut data_rng = Pcg64::with_stream(data_seed, 555);
    let batch = 8;
    let lr = opt.sched.alpha.at(0);
    let mut out = Vec::new();
    for _ in 0..steps {
        let x = data_rng.gaussian_matrix(widths[0], batch);
        let labels: Vec<usize> =
            (0..batch).map(|_| data_rng.below(widths[widths.len() - 1])).collect();
        net.train_batch(&x, &labels, true);
        let deltas = {
            let caps = net.kfac_captures();
            opt.step(0, &caps)
        };
        for d in &deltas {
            out.push(d.as_slice().to_vec());
        }
        net.apply_steps(&deltas, lr, 0.0);
    }
    out
}

const WIDTHS: [usize; 3] = [12, 10, 6];

/// Advertises update support, declines every attempt. Shares RSVD's key so
/// `OnlineMode::Rsvd` routes it onto the online path.
struct DecliningRsvd;

impl Decomposition for DecliningRsvd {
    fn key(&self) -> &str {
        "rsvd"
    }

    fn decompose(&self, m: &Matrix, cfg: &SketchConfig, rng: &mut Pcg64) -> LowRankFactor {
        decomposition::Rsvd.decompose(m, cfg, rng)
    }

    fn meta(&self, dim: usize, cfg: &SketchConfig) -> DecompMeta {
        decomposition::Rsvd.meta(dim, cfg)
    }

    fn supports_update(&self) -> bool {
        true
    }
    // `update` stays the trait default: Declined. `update_meta` stays None,
    // which also exercises the flops-prediction fallback to `meta`.
}

/// Contract 1: every refresh attempts the update, every attempt declines,
/// and the fallback decomposition — drawn from the same RNG stream the
/// plain engine uses — keeps training bitwise identical.
#[test]
fn decline_fallback_is_bitwise_recompute() {
    let mut net_a = models::mlp(&WIDTHS, 17);
    let mut net_b = models::mlp(&WIDTHS, 17);
    let dims = net_a.kfac_dims();
    let mut plain = KfacOptimizer::new(Arc::new(decomposition::Rsvd), sched(5, 2), &dims, 17);
    let mut declining = KfacOptimizer::new(Arc::new(DecliningRsvd), sched(5, 2), &dims, 17);
    assert!(declining.set_online(OnlineMode::Rsvd, 4), "DecliningRsvd advertises update support");

    let da = run_native(&mut plain, &mut net_a, &WIDTHS, 6, 99);
    let db = run_native(&mut declining, &mut net_b, &WIDTHS, 6, 99);
    assert_eq!(da.len(), db.len());
    for (i, (x, y)) in da.iter().zip(db.iter()).enumerate() {
        assert_eq!(x, y, "delta {i}: declined-update run diverged from plain recompute");
    }
    assert_eq!(declining.online_updates(), 0, "every attempt declined");
    assert!(declining.full_decomps() > 0, "declines must fall back to full decompositions");
}

/// Contract 2: `online = off` (explicitly set or never mentioned) is the
/// recompute engine — identical steps, identical checkpoint bytes. This is
/// what keeps the pre-online golden suites byte-stable.
#[test]
fn online_off_is_byte_identical_including_checkpoints() {
    let mut net_a = models::mlp(&WIDTHS, 23);
    let mut net_b = models::mlp(&WIDTHS, 23);
    let dims = net_a.kfac_dims();
    let mut untouched = KfacOptimizer::new(Arc::new(decomposition::Rsvd), sched(5, 2), &dims, 23);
    let mut explicit_off =
        KfacOptimizer::new(Arc::new(decomposition::Rsvd), sched(5, 2), &dims, 23);
    assert!(
        !explicit_off.set_online(OnlineMode::Off, 4),
        "Off must report online refresh inactive"
    );

    let da = run_native(&mut untouched, &mut net_a, &WIDTHS, 5, 7);
    let db = run_native(&mut explicit_off, &mut net_b, &WIDTHS, 5, 7);
    assert_eq!(da, db, "online = off changed step values");
    assert_eq!(
        untouched.save_state_bytes(),
        explicit_off.save_state_bytes(),
        "online = off changed the checkpoint byte stream"
    );
}

fn decayed_psd(rng: &mut Pcg64, d: usize, decay: f64) -> Matrix {
    let q = rkfac::linalg::qr::orthonormalize(&rng.gaussian_matrix(d, d));
    let lam: Vec<f64> = (0..d).map(|i| decay.powi(i as i32)).collect();
    let mut qd = q.clone();
    gemm::scale_cols(&mut qd, &lam);
    gemm::matmul_nt(&qd, &q)
}

/// Contract 3: on a decayed-spectrum PSD factor the updated basis's
/// reconstruction error (against the densely-updated matrix) stays within
/// a small multiple of what a *fresh* RSVD of that matrix achieves — the
/// update is allowed the prior basis's truncation error, nothing more.
#[test]
fn update_error_envelope_vs_fresh_rsvd() {
    let mut rng = Pcg64::new(41);
    let d = 32;
    let rank = 8;
    let cfg = SketchConfig::new(rank, 4, 2);
    let x0 = decayed_psd(&mut rng, d, 0.55);
    let strategy = decomposition::Rsvd;

    let mut job_rng = Pcg64::with_stream(3, 1);
    let prev = strategy.decompose(&x0, &cfg, &mut job_rng);

    let rho = 0.9;
    let u = rng.gaussian_matrix(d, 3);
    let delta = rkfac::rnla::FactorDelta::from_capture(&u, rho, u.cols() as f64);
    let mut dense = x0.clone();
    gemm::ea_gram_update(&mut dense, rho, &u, u.cols() as f64);

    let updated = match strategy.update(&prev, &delta, &cfg, &mut job_rng.clone()) {
        UpdateOutcome::Updated(f) => f,
        UpdateOutcome::Declined => panic!("rsvd must accept a non-empty basis"),
    };
    assert_eq!(updated.rank(), rank);

    let fresh = strategy.decompose(&dense, &cfg, &mut Pcg64::with_stream(3, 2));
    let err_updated = updated.reconstruct().rel_err(&dense);
    let err_fresh = fresh.reconstruct().rel_err(&dense);
    assert!(
        err_updated <= 2.0 * err_fresh + 0.02,
        "online update error {err_updated:.3e} blew the envelope around fresh RSVD \
         ({err_fresh:.3e})"
    );
}

/// Contract 4: checkpointing mid-accumulation (deltas pending, counters
/// non-zero) and resuming into a fresh online engine reproduces the
/// uninterrupted run bitwise — including the remaining update/correction
/// cadence.
#[test]
fn checkpoint_roundtrip_preserves_incremental_state_bitwise() {
    let dims: Vec<(usize, usize)>;
    // Uninterrupted reference: 9 steps straight.
    let mut net_ref = models::mlp(&WIDTHS, 31);
    dims = net_ref.kfac_dims();
    let mut reference = KfacOptimizer::new(Arc::new(decomposition::Rsvd), sched(5, 2), &dims, 31);
    assert!(reference.set_online(OnlineMode::Rsvd, 3));
    let all = run_native(&mut reference, &mut net_ref, &WIDTHS, 9, 13);

    // Interrupted run: 5 steps, checkpoint, restore into a fresh engine,
    // 4 more steps. The data stream is replayed deterministically.
    let mut net = models::mlp(&WIDTHS, 31);
    let mut first = KfacOptimizer::new(Arc::new(decomposition::Rsvd), sched(5, 2), &dims, 31);
    assert!(first.set_online(OnlineMode::Rsvd, 3));
    let mut data_rng = Pcg64::with_stream(13, 555);
    let batch = 8;
    let lr = first.sched.alpha.at(0);
    let mut head = Vec::new();
    for _ in 0..5 {
        let x = data_rng.gaussian_matrix(WIDTHS[0], batch);
        let labels: Vec<usize> = (0..batch).map(|_| data_rng.below(WIDTHS[2])).collect();
        net.train_batch(&x, &labels, true);
        let deltas = {
            let caps = net.kfac_captures();
            first.step(0, &caps)
        };
        for d in &deltas {
            head.push(d.as_slice().to_vec());
        }
        net.apply_steps(&deltas, lr, 0.0);
    }
    let blob = first.save_state_bytes();

    let mut resumed = KfacOptimizer::new(Arc::new(decomposition::Rsvd), sched(5, 2), &dims, 31);
    assert!(resumed.set_online(OnlineMode::Rsvd, 3));
    resumed.load_state_bytes(&blob).expect("online checkpoint must restore");
    assert_eq!(resumed.online_updates(), first.online_updates());
    assert_eq!(resumed.full_decomps(), first.full_decomps());
    // Round-trip stability: re-serializing the restored engine reproduces
    // the blob byte for byte (pending deltas included).
    assert_eq!(blob, resumed.save_state_bytes(), "restored state re-serializes differently");

    let mut tail = Vec::new();
    for _ in 0..4 {
        let x = data_rng.gaussian_matrix(WIDTHS[0], batch);
        let labels: Vec<usize> = (0..batch).map(|_| data_rng.below(WIDTHS[2])).collect();
        net.train_batch(&x, &labels, true);
        let deltas = {
            let caps = net.kfac_captures();
            resumed.step(0, &caps)
        };
        for d in &deltas {
            tail.push(d.as_slice().to_vec());
        }
        net.apply_steps(&deltas, lr, 0.0);
    }
    head.extend(tail);
    assert_eq!(all, head, "resumed online run diverged from the uninterrupted one");
}

/// Contract 5: the update path truncates to whatever rank `tune` selects —
/// adaptive-sketch feedback composes with online refreshes unchanged.
#[test]
fn tune_interaction_truncates_update_to_tuned_rank() {
    let mut rng = Pcg64::new(8);
    let d = 20;
    let base = SketchConfig::new(10, 4, 2);
    let x0 = decayed_psd(&mut rng, d, 0.6);
    let strategy = decomposition::Rsvd;
    let prev = strategy.decompose(&x0, &base, &mut Pcg64::with_stream(1, 1));

    let u = rng.gaussian_matrix(d, 2);
    let delta = rkfac::rnla::FactorDelta::from_capture(&u, 0.9, 2.0);
    for target_rank in [4usize, 10, 14] {
        let tuned = strategy.tune(&base, target_rank, 0.05);
        assert_eq!(tuned.rank, target_rank);
        let got = match strategy.update(&prev, &delta, &tuned, &mut Pcg64::with_stream(1, 2)) {
            UpdateOutcome::Updated(f) => f,
            UpdateOutcome::Declined => panic!("rsvd must accept a non-empty basis"),
        };
        let expect = target_rank.min(prev.rank() + delta.n_cols()).min(d);
        assert_eq!(got.rank(), expect, "tuned rank {target_rank} not honoured");
    }
}

/// Contract 6: with `online = rsvd` and `correction_every = 4`, only every
/// fourth refresh round (plus round 0) runs full decompositions — the
/// update counter carries the rest. T_KI = 1 makes every step a round, so
/// 8 steps = 8 rounds = 2 correction rounds and 6 update rounds, at two
/// factor sides per block.
#[test]
fn online_mode_cuts_full_decompositions_to_the_correction_cadence() {
    let mut net = models::mlp(&WIDTHS, 53);
    let dims = net.kfac_dims();
    let n_blocks = dims.len();
    let mut opt = KfacOptimizer::new(Arc::new(decomposition::Rsvd), sched(5, 1), &dims, 53);
    assert!(opt.set_online(OnlineMode::Rsvd, 4));

    run_native(&mut opt, &mut net, &WIDTHS, 8, 5);
    assert_eq!(opt.n_decomps, 8, "T_KI = 1: every step refreshes");

    // Rounds 0 and 4 are corrections; rounds 1-3 and 5-7 ship updates.
    let sides = 2 * n_blocks;
    assert_eq!(opt.full_decomps(), 2 * sides, "corrections at rounds 0 and 4 only");
    assert_eq!(opt.online_updates(), 6 * sides, "all non-correction rounds must update");
    // The acceptance shape: far fewer full decompositions than rounds.
    assert!(opt.online_updates() >= 2 * opt.full_decomps());
}
