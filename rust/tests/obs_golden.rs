//! Obs non-perturbation goldens.
//!
//! The observability subsystem's core contract is that it *observes*: it
//! reads the wall clock and records spans/metrics, but never feeds a value
//! back into compute or RNG state. These tests pin that contract bitwise —
//! a training run with `[obs]` fully enabled (JSONL + Chrome trace
//! exports) must reproduce the obs-disabled run's per-epoch losses and
//! accuracies exactly, on both the inline solver path and the async
//! pipeline at `max_stale_steps = 0` — and check that the files an
//! obs-enabled run writes are well-formed (parseable JSONL with a leading
//! meta line, a Chrome trace with a `traceEvents` array) and feed
//! `rkfac report`.
//!
//! The obs gate and event buffers are process-wide, so every test in this
//! file serializes on one lock (this integration binary is its own
//! process; the library's unit tests use their own internal guard).

use std::path::PathBuf;
use std::sync::{Mutex, MutexGuard};

use rkfac::coordinator::{DataChoice, EngineChoice, ModelChoice, Session, TrainConfig};
use rkfac::pipeline::PipelineConfig;
use rkfac::util::json::{self, Json};

static OBS_LOCK: Mutex<()> = Mutex::new(());

fn obs_lock() -> MutexGuard<'static, ()> {
    OBS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// The session suite's tiny deterministic run: [108, 32, 10] MLP on
/// synthetic data, 3 epochs — small enough that the golden pair runs in
/// seconds, big enough to exercise refresh rounds.
fn tiny_cfg(solver: &str, out_dir: &str) -> TrainConfig {
    TrainConfig {
        solver: solver.into(),
        epochs: 3,
        batch: 32,
        seed: 1,
        model: ModelChoice::Mlp { widths: vec![108, 32, 10] },
        data: DataChoice::Synthetic {
            n_train: 320,
            n_test: 96,
            height: 6,
            width: 6,
            channels: 3,
        },
        engine: EngineChoice::Native,
        targets: vec![0.5],
        augment: false,
        out_dir: out_dir.into(),
        sched_width: 0,
        ..Default::default()
    }
}

fn scratch_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("rkfac_obs_golden_{tag}_{}", std::process::id()))
}

/// The per-epoch series a run is judged on, bitwise.
fn series(cfg: TrainConfig) -> Vec<(f64, f64, f64)> {
    let r = Session::new(cfg).run().unwrap();
    assert_eq!(r.records.len(), 3);
    r.records.iter().map(|e| (e.train_loss, e.test_loss, e.test_acc)).collect()
}

/// Run the obs-off / obs-on golden pair for one config and return the
/// obs run's out_dir (exports left in place for the caller to inspect).
fn assert_obs_is_non_perturbing(label: &str, base: TrainConfig) -> PathBuf {
    let dir = scratch_dir(label);
    std::fs::remove_dir_all(&dir).ok();
    let mut with_obs = base.clone();
    with_obs.obs.enabled = true;
    with_obs.obs.summary = false; // keep test output quiet
    with_obs.out_dir = dir.to_str().unwrap().to_string();
    let baseline = series(base);
    let observed = series(with_obs);
    for (epoch, (a, b)) in baseline.iter().zip(observed.iter()).enumerate() {
        assert_eq!(a, b, "{label}: epoch {epoch} diverged with obs enabled");
    }
    dir
}

/// Inline solver path: kfac+rsvd with obs fully enabled is bitwise
/// identical to the obs-disabled run.
#[test]
fn obs_enabled_native_run_is_bitwise_identical() {
    let _g = obs_lock();
    let dir = assert_obs_is_non_perturbing("native", tiny_cfg("rs-kfac", "/tmp/rkfac_obs_base"));

    // The run also left well-formed exports behind.
    let jsonl = dir.join("obs_rs-kfac_1.jsonl");
    let trace = dir.join("trace_rs-kfac_1.json");
    let text = std::fs::read_to_string(&jsonl).unwrap();
    let mut lines = text.lines();
    let meta = json::parse(lines.next().unwrap()).unwrap();
    assert_eq!(meta.get("type").and_then(Json::as_str), Some("meta"));
    assert_eq!(meta.get("schema").and_then(Json::as_usize), Some(1));
    assert_eq!(meta.get("solver").and_then(Json::as_str), Some("rs-kfac"));
    let mut names = std::collections::BTreeSet::new();
    for line in lines {
        let v = json::parse(line).unwrap();
        if v.get("type").and_then(Json::as_str) == Some("span") {
            names.insert(v.get("name").and_then(Json::as_str).unwrap().to_string());
        }
    }
    for expected in [
        "run",
        "epoch",
        "step",
        "step.data",
        "step.forward_backward",
        "step.precondition",
        "step.apply",
        "kfac.refresh",
        "kfac.refresh.rsvd",
        "epoch.evaluate",
    ] {
        assert!(names.contains(expected), "missing span '{expected}' in {names:?}");
    }

    let chrome = json::parse(&std::fs::read_to_string(&trace).unwrap()).unwrap();
    let events = chrome.get("traceEvents").and_then(Json::as_arr).unwrap();
    assert!(!events.is_empty(), "Chrome trace has no events");
    for ev in events {
        assert_eq!(ev.get("ph").and_then(Json::as_str), Some("X"));
        assert!(ev.get("dur").and_then(Json::as_f64).is_some());
    }

    // And the cost-model report ingests them: step + refresh breakdowns
    // plus the predicted-vs-observed table keyed on the rsvd refreshes.
    let report = rkfac::obs::report::run_report(&dir).unwrap();
    assert!(report.contains("step breakdown"), "{report}");
    assert!(report.contains("refresh breakdown"), "{report}");
    assert!(report.contains("cost model"), "{report}");
    assert!(report.contains("rsvd"), "{report}");

    std::fs::remove_dir_all(&dir).ok();
}

/// Async pipeline path at `max_stale_steps = 0` (bitwise-synchronous by
/// the pipeline contract): still bitwise identical with obs enabled, and
/// the worker-side spans carry the queue-wait/run split.
#[test]
fn obs_enabled_pipelined_run_is_bitwise_identical() {
    let _g = obs_lock();
    let mut cfg = tiny_cfg("rs-kfac", "/tmp/rkfac_obs_base_pipe");
    cfg.pipeline = PipelineConfig {
        enabled: true,
        workers: 2,
        max_stale_steps: 0,
        ..Default::default()
    };
    let dir = assert_obs_is_non_perturbing("pipelined", cfg);

    let text = std::fs::read_to_string(dir.join("obs_rs-kfac_1.jsonl")).unwrap();
    let (mut waits, mut runs) = (0usize, 0usize);
    for line in text.lines().skip(1) {
        let v = json::parse(line).unwrap();
        match v.get("name").and_then(Json::as_str) {
            Some("pipeline.job.wait") => waits += 1,
            Some("pipeline.job.run") => {
                runs += 1;
                // Worker spans carry the cost-model join keys.
                let args = v.get("args").unwrap();
                assert!(args.get("block").is_some());
                assert!(args.get("flops_pred").and_then(Json::as_f64).is_some());
                assert!(args.get("strategy").and_then(Json::as_str).is_some());
            }
            _ => {}
        }
    }
    assert!(waits > 0, "no pipeline.job.wait spans recorded");
    assert_eq!(waits, runs, "every popped job has one wait and one run span");
    std::fs::remove_dir_all(&dir).ok();
}
