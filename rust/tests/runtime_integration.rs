//! Integration: Rust runtime ⇄ AOT artifacts (requires `make artifacts`).
//!
//! These tests load the real HLO-text artifacts through the PJRT CPU client
//! and cross-check the numerics against the Rust-native linalg oracles —
//! the L3-native mirror of what pytest does against the jnp refs at L1/L2.

use std::sync::Arc;

use rkfac::linalg::{gemm, Matrix, Pcg64};
use rkfac::runtime::{CompiledModel, Engine, HostTensor};

fn artifact_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Artifacts are produced by `make artifacts` (Python/JAX toolchain) and
/// executed through the real `xla` crate; clean offline checkouts have
/// neither, so these tests self-skip instead of failing the tier-1 run.
fn artifacts_ready() -> bool {
    let ok = artifact_dir().join("manifest.json").exists();
    if !ok {
        eprintln!("skipping: artifacts/manifest.json not found (run `make artifacts`)");
    }
    ok
}

fn engine() -> Arc<Engine> {
    Arc::new(Engine::new(artifact_dir()).expect("run `make artifacts` before cargo test"))
}

#[test]
fn registry_lists_expected_artifacts() {
    if !artifacts_ready() {
        return;
    }
    let eng = engine();
    let names = eng.registry().names();
    for required in [
        "mlp_step_tiny",
        "mlp_eval_tiny",
        "mlp_sgd_tiny",
        "ea_gram_256x128",
        "lowrank_apply_256_64_256",
        "sketch_256_74",
    ] {
        assert!(names.contains(&required), "missing artifact {required}; have {names:?}");
    }
    assert!(eng.registry().of_kind("model").len() >= 3);
}

#[test]
fn ea_gram_artifact_matches_native_kernel() {
    if !artifacts_ready() {
        return;
    }
    let eng = engine();
    let mut rng = Pcg64::new(1);
    let d = 256;
    let n = 128;
    let mut old = rng.gaussian_matrix(d, d);
    old.symmetrize();
    let m = rng.gaussian_matrix(d, n);
    let out = eng
        .execute("ea_gram_256x128", &[HostTensor::from_matrix(&old), HostTensor::from_matrix(&m)])
        .unwrap();
    let got = out[0].to_matrix();
    // Native mirror: rho=0.95, denom=128 (the AOT-baked constants).
    let mut expect = old.clone();
    gemm::ea_gram_update(&mut expect, 0.95, &m, 128.0);
    assert!(got.rel_err(&expect) < 1e-4, "rel err {}", got.rel_err(&expect));
}

#[test]
fn lowrank_apply_artifact_matches_eq13() {
    use rkfac::linalg::evd::sym_evd;
    use rkfac::rnla::LowRankFactor;
    if !artifacts_ready() {
        return;
    }
    let eng = engine();
    let mut rng = Pcg64::new(2);
    let (d, r, c) = (256, 64, 256);
    // Build a PSD matrix, take its exact top-r eigenpairs as U/D inputs.
    let g = rng.gaussian_matrix(d, d + 8);
    let psd = gemm::syrk(&g);
    let e = sym_evd(&psd);
    let u = e.u.first_cols(r);
    let dvals: Vec<f64> = e.lambda[..r].to_vec();
    let v = rng.gaussian_matrix(d, c);
    let lam = 0.5f64;

    let out = eng
        .execute(
            "lowrank_apply_256_64_256",
            &[
                HostTensor::from_matrix(&u),
                HostTensor::vec1(dvals.iter().map(|&x| x as f32).collect()),
                HostTensor::scalar(lam as f32),
                HostTensor::from_matrix(&v),
            ],
        )
        .unwrap();
    let got = out[0].to_matrix();
    let expect = LowRankFactor::new(u, dvals).damped_inverse_apply(lam, &v);
    // f32 kernel with O(1/λ) cancellation: tolerance scaled accordingly.
    assert!(got.rel_err(&expect) < 5e-3, "rel err {}", got.rel_err(&expect));
}

#[test]
fn sketch_artifact_matches_native_matmul() {
    if !artifacts_ready() {
        return;
    }
    let eng = engine();
    let mut rng = Pcg64::new(3);
    let x = rng.gaussian_matrix(256, 256);
    let om = rng.gaussian_matrix(256, 74);
    let out = eng
        .execute("sketch_256_74", &[HostTensor::from_matrix(&x), HostTensor::from_matrix(&om)])
        .unwrap();
    let got = out[0].to_matrix();
    let expect = gemm::matmul(&x, &om);
    assert!(got.rel_err(&expect) < 1e-4, "rel err {}", got.rel_err(&expect));
}

#[test]
fn model_step_zero_weights_gives_log_c_loss() {
    if !artifacts_ready() {
        return;
    }
    let eng = engine();
    let model = CompiledModel::new(eng, "tiny").unwrap();
    let n = model.n_layers();
    let ws: Vec<Matrix> =
        model.weight_shapes().iter().map(|&(o, i)| Matrix::zeros(o, i)).collect();
    let (a, g) = model.init_factors();
    let mut rng = Pcg64::new(4);
    let x = rng.gaussian_matrix(model.widths()[0], model.batch());
    let mut y = Matrix::zeros(*model.widths().last().unwrap(), model.batch());
    let classes = y.rows();
    for b in 0..model.batch() {
        y[(b % classes, b)] = 1.0;
    }
    let out = model.step(&ws, &a, &g, &x, &y).unwrap();
    // Uniform softmax over C classes -> loss = ln(C).
    let c = *model.widths().last().unwrap() as f64;
    assert!((out.loss - c.ln()).abs() < 1e-5, "loss {} vs {}", out.loss, c.ln());
    assert_eq!(out.grads.len(), n);
    // Zero weights => zero activations after layer 1 => layer-1+ grads 0.
    assert!(out.grads[1].max_abs() < 1e-6);
    // EA factors: with identity init, new_A0 = 0.95 I + 0.05/B xxᵀ.
    let mut expect_a0 = Matrix::eye(model.widths()[0]);
    gemm::ea_gram_update(&mut expect_a0, 0.95, &x, model.batch() as f64);
    assert!(out.a_factors[0].rel_err(&expect_a0) < 1e-4);
}

#[test]
fn model_step_grads_match_finite_difference() {
    if !artifacts_ready() {
        return;
    }
    let eng = engine();
    let model = CompiledModel::new(eng, "tiny").unwrap();
    let mut rng = Pcg64::new(5);
    let ws = model.init_weights(&mut rng);
    let (a, g) = model.init_factors();
    let x = rng.gaussian_matrix(model.widths()[0], model.batch());
    let mut y = Matrix::zeros(*model.widths().last().unwrap(), model.batch());
    let classes = y.rows();
    for b in 0..model.batch() {
        y[(rng.below(classes), b)] = 1.0;
    }
    let out = model.step(&ws, &a, &g, &x, &y).unwrap();
    // Central finite differences on a few weight entries of layer 0.
    let eps = 1e-2;
    for &(i, j) in &[(0usize, 0usize), (3, 7), (10, 20)] {
        let mut wp = ws.clone();
        wp[0][(i, j)] += eps;
        let lp = model.step(&wp, &a, &g, &x, &y).unwrap().loss;
        let mut wm = ws.clone();
        wm[0][(i, j)] -= eps;
        let lm = model.step(&wm, &a, &g, &x, &y).unwrap().loss;
        let fd = (lp - lm) / (2.0 * eps);
        let an = out.grads[0][(i, j)];
        assert!(
            (fd - an).abs() < 2e-3 * an.abs().max(0.1),
            "grad[0][({i},{j})]: fd {fd} vs analytic {an}"
        );
    }
}

#[test]
fn model_eval_counts_and_sgd_descends() {
    if !artifacts_ready() {
        return;
    }
    let eng = engine();
    let model = CompiledModel::new(eng, "tiny").unwrap();
    let mut rng = Pcg64::new(6);
    let mut ws = model.init_weights(&mut rng);
    let x = rng.gaussian_matrix(model.widths()[0], model.batch());
    let mut y = Matrix::zeros(*model.widths().last().unwrap(), model.batch());
    let classes = y.rows();
    for b in 0..model.batch() {
        y[(b % classes, b)] = 1.0;
    }
    let (loss0, correct0) = model.eval(&ws, &x, &y).unwrap();
    assert!(correct0 <= model.batch());
    assert!(loss0 > 0.0);
    // A few fused-SGD steps on the same batch must reduce the loss.
    let mut last = loss0;
    for _ in 0..5 {
        let (l, ws_new) = model.sgd(&ws, &x, &y).unwrap();
        ws = ws_new;
        last = l;
    }
    let (loss1, _) = model.eval(&ws, &x, &y).unwrap();
    assert!(loss1 < loss0, "SGD failed to descend: {loss0} -> {loss1} (last step {last})");
}

#[test]
fn engine_rejects_bad_shapes() {
    if !artifacts_ready() {
        return;
    }
    let eng = engine();
    let bad = vec![HostTensor::zeros(vec![3, 3])];
    let err = eng.execute("ea_gram_256x128", &bad).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("expected") || msg.contains("shape"), "msg: {msg}");
}
