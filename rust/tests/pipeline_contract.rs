//! Contract tests for the async factor-refresh pipeline
//! (`rkfac::pipeline`): the guarantees the subsystem advertises.
//!
//! 1. **Bounded staleness** — after any refresh at step `s`, every
//!    published decomposition has version ≥ `s − max_stale_steps`.
//! 2. **Zero-staleness equivalence** — with `max_stale_steps = 0` (and the
//!    global schedule rank) the async path reproduces the synchronous
//!    inline path *bitwise*, because both draw decomposition randomness
//!    from the shared per-(round, block, side) streams. This holds under
//!    **both** queue disciplines (`fifo` and `flops-stale`): scheduling
//!    order never leaks into values.
//! 3. **Adaptive-rank monotonicity** — a tighter error target never
//!    selects a smaller rank.
//! 4. **Failure recovery** — a decomposition panic on a worker is re-run
//!    inline on the trainer thread with the same deterministic RNG, so
//!    training completes bitwise as if nothing failed.
//! 5. **Zero-copy snapshots** — enqueueing a job shares the trainer's
//!    `Arc<Matrix>` EA snapshot instead of cloning the matrix.
//!
//! Most run as seeded property tests over random schedules, staleness
//! budgets, worker counts, and spectra (`rkfac::util::prop`).

use std::sync::Arc;

use rkfac::linalg::{Matrix, Pcg64};
use rkfac::optim::kfac::BlockState;
use rkfac::optim::schedules::{KfacSchedules, StepSchedule};
use rkfac::optim::KfacOptimizer;
use rkfac::pipeline::{next_rank, FactorPipeline, PipelineConfig, Schedule};
use rkfac::rnla::{decomposition, DecompMeta, Decomposition, LowRankFactor, SketchConfig};
use rkfac::util::prop::{check, ensure, Gen};

fn quick_sched(rank: usize, t_ki: usize) -> KfacSchedules {
    KfacSchedules {
        rho: 0.9,
        t_ku: 1,
        t_ki: StepSchedule::constant(t_ki as f64),
        lambda: StepSchedule::constant(0.1),
        alpha: StepSchedule::constant(0.2),
        rank: StepSchedule::constant(rank as f64),
        oversample: StepSchedule::constant(4.0),
        n_power_iter: 1,
        weight_decay: 0.0,
    }
}

type FactorSet = (Vec<Matrix>, Vec<Matrix>, Vec<Matrix>);

fn synth_factors(g: &mut Gen<'_>, dims: &[(usize, usize)]) -> FactorSet {
    let a = dims.iter().map(|&(da, _)| g.decaying_psd(da, 0.7)).collect();
    let gm = dims.iter().map(|&(_, dg)| g.decaying_psd(dg, 0.7)).collect();
    let grads = dims.iter().map(|&(da, dg)| g.matrix(dg, da)).collect();
    (a, gm, grads)
}

/// Contract 1: a published factor is never older than `max_stale_steps`
/// relative to the most recent refresh, for random T_KI / staleness budgets
/// / worker counts.
#[test]
fn published_factor_never_older_than_max_stale() {
    check("pipeline-staleness-bound", 10, |g| {
        let t_ki = g.usize_in(1, 4);
        let stale = g.usize_in(0, 3);
        let workers = g.usize_in(1, 3);
        let dims = [(10usize, 8usize), (8, 6)];
        let mut opt =
            KfacOptimizer::new(Arc::new(decomposition::Rsvd), quick_sched(6, t_ki), &dims, 9);
        opt.attach_pipeline(PipelineConfig {
            enabled: true,
            workers,
            max_stale_steps: stale,
            ..Default::default()
        });
        let mut last_refresh: Option<u64> = None;
        for step in 0..12u64 {
            let (a, gm, grads) = synth_factors(g, &dims);
            let grad_refs: Vec<&Matrix> = grads.iter().collect();
            let before = opt.n_decomps;
            let _ = opt.step_with_factors(0, a, gm, &grad_refs);
            if opt.n_decomps > before {
                last_refresh = Some(step);
            }
            if let Some(rs) = last_refresh {
                let required = rs.saturating_sub(stale as u64);
                for (slot, v) in
                    opt.pipeline().unwrap().published_versions().into_iter().enumerate()
                {
                    let v = v.ok_or_else(|| format!("slot {slot} unpublished after refresh"))?;
                    ensure(
                        v >= required,
                        format!(
                            "slot {slot}: version {v} older than required {required} \
                             (refresh step {rs}, stale budget {stale})"
                        ),
                    )?;
                }
            }
        }
        Ok(())
    });
}

/// Contract 2: with staleness forced to 0 the async path bitwise-matches
/// the synchronous inline path, step for step.
#[test]
fn zero_staleness_bitwise_matches_sync() {
    check("pipeline-zero-staleness-equivalence", 6, |g| {
        let t_ki = g.usize_in(1, 3);
        let workers = g.usize_in(1, 3);
        let dims = [(12usize, 10usize), (10, 8)];
        let mut sync =
            KfacOptimizer::new(Arc::new(decomposition::Rsvd), quick_sched(6, t_ki), &dims, 21);
        let mut piped =
            KfacOptimizer::new(Arc::new(decomposition::Rsvd), quick_sched(6, t_ki), &dims, 21);
        piped.attach_pipeline(PipelineConfig {
            enabled: true,
            workers,
            max_stale_steps: 0,
            ..Default::default()
        });
        for step in 0..8 {
            let (a, gm, grads) = synth_factors(g, &dims);
            let grad_refs: Vec<&Matrix> = grads.iter().collect();
            let d_sync = sync.step_with_factors(0, a.clone(), gm.clone(), &grad_refs);
            let d_piped = piped.step_with_factors(0, a, gm, &grad_refs);
            for (bi, (x, y)) in d_sync.iter().zip(d_piped.iter()).enumerate() {
                ensure(
                    x.as_slice() == y.as_slice(),
                    format!("step {step} block {bi}: async delta differs from sync"),
                )?;
            }
        }
        Ok(())
    });
}

/// Contract 3: the adaptive rank controller is monotone in the error
/// target — tightening ε never shrinks the selected rank.
#[test]
fn rank_controller_monotone_in_error_target() {
    check("pipeline-rank-monotone", 64, |g| {
        let n = g.usize_in(4, 40);
        let decay = g.f64_in(0.3, 0.98);
        let lambda: Vec<f64> = (0..n).map(|i| decay.powi(i as i32)).collect();
        let current = g.usize_in(1, 48);
        // Retained head, as a real decomposition of rank `current` reports.
        let head: Vec<f64> = lambda.iter().take(current.min(n)).copied().collect();
        let t1 = g.f64_in(1e-4, 0.4);
        let t2 = g.f64_in(1e-4, 0.4);
        let (tight, loose) = if t1 <= t2 { (t1, t2) } else { (t2, t1) };
        let min_rank = g.usize_in(1, 4);
        let max_rank = g.usize_in(8, 64);
        let growth = g.f64_in(1.1, 2.5);
        let r_tight = next_rank(&head, current, tight, min_rank, max_rank, growth);
        let r_loose = next_rank(&head, current, loose, min_rank, max_rank, growth);
        ensure(
            r_tight >= r_loose,
            format!(
                "target {tight:.4} chose rank {r_tight} < rank {r_loose} of looser \
                 target {loose:.4} (current {current}, |head| {})",
                head.len()
            ),
        )
    });
}

/// Contract 2b: the queue discipline is value-invariant — `fifo` and
/// `flops-stale` schedules publish bitwise-identical factors at
/// `max_stale_steps = 0`, for random T_KI and worker counts.
#[test]
fn priority_and_fifo_schedules_bitwise_identical_at_zero_staleness() {
    check("pipeline-schedule-equivalence", 6, |g| {
        let t_ki = g.usize_in(1, 3);
        let dims = [(12usize, 10usize), (10, 8)];
        let mut opts: Vec<KfacOptimizer> = [Schedule::Fifo, Schedule::FlopsStale]
            .into_iter()
            .map(|schedule| {
                let mut opt = KfacOptimizer::new(
                    Arc::new(decomposition::Rsvd),
                    quick_sched(6, t_ki),
                    &dims,
                    27,
                );
                opt.attach_pipeline(PipelineConfig {
                    enabled: true,
                    workers: g.usize_in(1, 3),
                    max_stale_steps: 0,
                    schedule,
                    ..Default::default()
                });
                opt
            })
            .collect();
        for step in 0..6 {
            let (a, gm, grads) = synth_factors(g, &dims);
            let grad_refs: Vec<&Matrix> = grads.iter().collect();
            let mut deltas = Vec::new();
            for opt in opts.iter_mut() {
                deltas.push(opt.step_with_factors(0, a.clone(), gm.clone(), &grad_refs));
            }
            for (bi, (x, y)) in deltas[0].iter().zip(deltas[1].iter()).enumerate() {
                ensure(
                    x.as_slice() == y.as_slice(),
                    format!("step {step} block {bi}: fifo and flops-stale deltas differ"),
                )?;
            }
        }
        Ok(())
    });
}

/// Contract 4: a strategy that panics on every worker thread (but works on
/// the trainer thread) must not abort training — each job completes via
/// the inline retry, counted in `recovered_jobs`, and the run is bitwise
/// what a healthy run with the same underlying strategy produces.
struct PoisonedOnWorkers;

impl Decomposition for PoisonedOnWorkers {
    fn key(&self) -> &str {
        "poisoned"
    }

    fn decompose(&self, m: &Matrix, cfg: &SketchConfig, rng: &mut Pcg64) -> LowRankFactor {
        if std::thread::current().name().is_some_and(|n| n.starts_with("factor-refresh")) {
            panic!("poisoned strategy: refuses to run on pipeline workers");
        }
        decomposition::Rsvd.decompose(m, cfg, rng)
    }

    fn meta(&self, dim: usize, cfg: &SketchConfig) -> DecompMeta {
        decomposition::Rsvd.meta(dim, cfg)
    }
}

#[test]
fn worker_panic_recovers_via_inline_retry() {
    let dims = [(10usize, 8usize)];
    let mut poisoned =
        KfacOptimizer::new(Arc::new(PoisonedOnWorkers), quick_sched(6, 1), &dims, 33);
    poisoned.attach_pipeline(PipelineConfig {
        enabled: true,
        workers: 2,
        max_stale_steps: 0,
        ..Default::default()
    });
    let mut healthy =
        KfacOptimizer::new(Arc::new(decomposition::Rsvd), quick_sched(6, 1), &dims, 33);
    let mut rng = Pcg64::new(4);
    let mut g = Gen { rng: &mut rng };
    for step in 0..3 {
        let (a, gm, grads) = synth_factors(&mut g, &dims);
        let grad_refs: Vec<&Matrix> = grads.iter().collect();
        let dp = poisoned.step_with_factors(0, a.clone(), gm.clone(), &grad_refs);
        let dh = healthy.step_with_factors(0, a, gm, &grad_refs);
        for (bi, (x, y)) in dp.iter().zip(dh.iter()).enumerate() {
            assert_eq!(
                x.as_slice(),
                y.as_slice(),
                "step {step} block {bi}: recovered run must match the healthy run bitwise"
            );
        }
    }
    let p = poisoned.pipeline().unwrap();
    assert!(p.recovered_jobs() >= 1, "at least one job must have been recovered");
    assert_eq!(
        p.recovered_jobs(),
        p.jobs_completed(),
        "every job panicked on its worker, so every completion is a recovery"
    );
}

/// Regression (mid-warmup staleness reporting): before any publish,
/// `max_staleness` is `None` and every slot counts as warming; once a
/// refresh ran, no slot is warming and the worst-case staleness is
/// reported — it must never collapse to `None` because some slot is
/// merely unpublished.
#[test]
fn max_staleness_during_warmup_ignores_unpublished_slots() {
    let dims = [(8usize, 6usize), (6, 5)];
    let mut opt = KfacOptimizer::new(Arc::new(decomposition::Rsvd), quick_sched(5, 2), &dims, 77);
    opt.attach_pipeline(PipelineConfig {
        enabled: true,
        workers: 1,
        max_stale_steps: 3,
        ..Default::default()
    });
    {
        let p = opt.pipeline().unwrap();
        assert_eq!(p.max_staleness(0), None, "nothing published yet");
        assert_eq!(p.warming(), 4, "all four slots cold before the first refresh");
    }
    let mut rng = Pcg64::new(6);
    let mut g = Gen { rng: &mut rng };
    for _ in 0..4 {
        let (a, gm, grads) = synth_factors(&mut g, &dims);
        let grad_refs: Vec<&Matrix> = grads.iter().collect();
        let _ = opt.step_with_factors(0, a, gm, &grad_refs);
        let p = opt.pipeline().unwrap();
        let now = opt.step_count as u64;
        if p.warming() == 0 {
            let worst = p.max_staleness(now).expect("published slots must report staleness");
            assert!(worst <= 3 + 2, "staleness {worst} beyond stale budget + T_KI");
        }
    }
    let p = opt.pipeline().unwrap();
    assert_eq!(p.warming(), 0, "everything published after four steps");
    assert!(p.max_staleness(opt.step_count as u64).is_some());
}

/// Contract 5: the refresh hot path never clones the EA matrices — jobs
/// share the trainer's `Arc` snapshot, so an untouched factor keeps its
/// allocation across rounds (pointer equality).
#[test]
fn refresh_shares_arc_snapshots_without_matrix_clones() {
    let mut rng = Pcg64::new(12);
    let mut g = Gen { rng: &mut rng };
    let (da, dg) = (10usize, 8usize);
    let mut blocks = vec![BlockState {
        a_bar: Arc::new(g.decaying_psd(da, 0.7)),
        g_bar: Arc::new(g.decaying_psd(dg, 0.7)),
        a_dec: LowRankFactor::new(Matrix::eye(da), vec![1.0; da]),
        g_dec: LowRankFactor::new(Matrix::eye(dg), vec![1.0; dg]),
        factored: None,
    }];
    let strat: Arc<dyn Decomposition> = Arc::new(decomposition::Rsvd);
    let base = SketchConfig::new(5, 3, 1);
    let mut p = FactorPipeline::new(
        PipelineConfig { enabled: true, workers: 2, max_stale_steps: 0, ..Default::default() },
        &[(da, dg)],
        5,
        0.95,
    );
    let pa = Arc::as_ptr(&blocks[0].a_bar);
    let pg = Arc::as_ptr(&blocks[0].g_bar);
    p.refresh(&mut blocks, &strat, &base, 5, 0, 0);
    p.refresh(&mut blocks, &strat, &base, 5, 1, 1);
    // The EA factors were untouched between rounds: still the same
    // allocations — refresh never deep-copied them into its jobs.
    assert_eq!(pa, Arc::as_ptr(&blocks[0].a_bar), "Ā was re-allocated by the refresh path");
    assert_eq!(pg, Arc::as_ptr(&blocks[0].g_bar), "Γ̄ was re-allocated by the refresh path");
    assert!(blocks[0].a_dec.u.all_finite());
}

/// The stale pipeline still preconditions with *some* published factor
/// while newer ones build: versions only ever move forward.
#[test]
fn published_versions_monotone_under_staleness() {
    check("pipeline-version-monotone", 6, |g| {
        let dims = [(10usize, 10usize)];
        let mut opt =
            KfacOptimizer::new(Arc::new(decomposition::Srevd), quick_sched(5, 2), &dims, 5);
        opt.attach_pipeline(PipelineConfig {
            enabled: true,
            workers: 1,
            max_stale_steps: g.usize_in(1, 4),
            ..Default::default()
        });
        let mut last: Vec<Option<u64>> = vec![None; 2];
        for _ in 0..10 {
            let (a, gm, grads) = synth_factors(g, &dims);
            let grad_refs: Vec<&Matrix> = grads.iter().collect();
            let _ = opt.step_with_factors(0, a, gm, &grad_refs);
            let now = opt.pipeline().unwrap().published_versions();
            for (slot, (prev, cur)) in last.iter().zip(now.iter()).enumerate() {
                if let (Some(p), Some(c)) = (prev, cur) {
                    ensure(c >= p, format!("slot {slot}: version moved backwards {p} -> {c}"))?;
                }
            }
            last = now;
        }
        Ok(())
    });
}
