//! Contract tests for the async factor-refresh pipeline
//! (`rkfac::pipeline`): the three guarantees the subsystem advertises.
//!
//! 1. **Bounded staleness** — after any refresh at step `s`, every
//!    published decomposition has version ≥ `s − max_stale_steps`.
//! 2. **Zero-staleness equivalence** — with `max_stale_steps = 0` (and the
//!    global schedule rank) the async path reproduces the synchronous
//!    inline path *bitwise*, because both draw decomposition randomness
//!    from the shared per-(round, block, side) streams.
//! 3. **Adaptive-rank monotonicity** — a tighter error target never
//!    selects a smaller rank.
//!
//! All three run as seeded property tests over random schedules, staleness
//! budgets, worker counts, and spectra (`rkfac::util::prop`).

use std::sync::Arc;

use rkfac::linalg::Matrix;
use rkfac::optim::schedules::{KfacSchedules, StepSchedule};
use rkfac::optim::KfacOptimizer;
use rkfac::pipeline::{next_rank, PipelineConfig};
use rkfac::rnla::decomposition;
use rkfac::util::prop::{check, ensure, Gen};

fn quick_sched(rank: usize, t_ki: usize) -> KfacSchedules {
    KfacSchedules {
        rho: 0.9,
        t_ku: 1,
        t_ki: StepSchedule::constant(t_ki as f64),
        lambda: StepSchedule::constant(0.1),
        alpha: StepSchedule::constant(0.2),
        rank: StepSchedule::constant(rank as f64),
        oversample: StepSchedule::constant(4.0),
        n_power_iter: 1,
        weight_decay: 0.0,
    }
}

type FactorSet = (Vec<Matrix>, Vec<Matrix>, Vec<Matrix>);

fn synth_factors(g: &mut Gen<'_>, dims: &[(usize, usize)]) -> FactorSet {
    let a = dims.iter().map(|&(da, _)| g.decaying_psd(da, 0.7)).collect();
    let gm = dims.iter().map(|&(_, dg)| g.decaying_psd(dg, 0.7)).collect();
    let grads = dims.iter().map(|&(da, dg)| g.matrix(dg, da)).collect();
    (a, gm, grads)
}

/// Contract 1: a published factor is never older than `max_stale_steps`
/// relative to the most recent refresh, for random T_KI / staleness budgets
/// / worker counts.
#[test]
fn published_factor_never_older_than_max_stale() {
    check("pipeline-staleness-bound", 10, |g| {
        let t_ki = g.usize_in(1, 4);
        let stale = g.usize_in(0, 3);
        let workers = g.usize_in(1, 3);
        let dims = [(10usize, 8usize), (8, 6)];
        let mut opt =
            KfacOptimizer::new(Arc::new(decomposition::Rsvd), quick_sched(6, t_ki), &dims, 9);
        opt.attach_pipeline(PipelineConfig {
            enabled: true,
            workers,
            max_stale_steps: stale,
            ..Default::default()
        });
        let mut last_refresh: Option<u64> = None;
        for step in 0..12u64 {
            let (a, gm, grads) = synth_factors(g, &dims);
            let grad_refs: Vec<&Matrix> = grads.iter().collect();
            let before = opt.n_decomps;
            let _ = opt.step_with_factors(0, a, gm, &grad_refs);
            if opt.n_decomps > before {
                last_refresh = Some(step);
            }
            if let Some(rs) = last_refresh {
                let required = rs.saturating_sub(stale as u64);
                for (slot, v) in
                    opt.pipeline().unwrap().published_versions().into_iter().enumerate()
                {
                    let v = v.ok_or_else(|| format!("slot {slot} unpublished after refresh"))?;
                    ensure(
                        v >= required,
                        format!(
                            "slot {slot}: version {v} older than required {required} \
                             (refresh step {rs}, stale budget {stale})"
                        ),
                    )?;
                }
            }
        }
        Ok(())
    });
}

/// Contract 2: with staleness forced to 0 the async path bitwise-matches
/// the synchronous inline path, step for step.
#[test]
fn zero_staleness_bitwise_matches_sync() {
    check("pipeline-zero-staleness-equivalence", 6, |g| {
        let t_ki = g.usize_in(1, 3);
        let workers = g.usize_in(1, 3);
        let dims = [(12usize, 10usize), (10, 8)];
        let mut sync =
            KfacOptimizer::new(Arc::new(decomposition::Rsvd), quick_sched(6, t_ki), &dims, 21);
        let mut piped =
            KfacOptimizer::new(Arc::new(decomposition::Rsvd), quick_sched(6, t_ki), &dims, 21);
        piped.attach_pipeline(PipelineConfig {
            enabled: true,
            workers,
            max_stale_steps: 0,
            ..Default::default()
        });
        for step in 0..8 {
            let (a, gm, grads) = synth_factors(g, &dims);
            let grad_refs: Vec<&Matrix> = grads.iter().collect();
            let d_sync = sync.step_with_factors(0, a.clone(), gm.clone(), &grad_refs);
            let d_piped = piped.step_with_factors(0, a, gm, &grad_refs);
            for (bi, (x, y)) in d_sync.iter().zip(d_piped.iter()).enumerate() {
                ensure(
                    x.as_slice() == y.as_slice(),
                    format!("step {step} block {bi}: async delta differs from sync"),
                )?;
            }
        }
        Ok(())
    });
}

/// Contract 3: the adaptive rank controller is monotone in the error
/// target — tightening ε never shrinks the selected rank.
#[test]
fn rank_controller_monotone_in_error_target() {
    check("pipeline-rank-monotone", 64, |g| {
        let n = g.usize_in(4, 40);
        let decay = g.f64_in(0.3, 0.98);
        let lambda: Vec<f64> = (0..n).map(|i| decay.powi(i as i32)).collect();
        let current = g.usize_in(1, 48);
        // Retained head, as a real decomposition of rank `current` reports.
        let head: Vec<f64> = lambda.iter().take(current.min(n)).copied().collect();
        let t1 = g.f64_in(1e-4, 0.4);
        let t2 = g.f64_in(1e-4, 0.4);
        let (tight, loose) = if t1 <= t2 { (t1, t2) } else { (t2, t1) };
        let min_rank = g.usize_in(1, 4);
        let max_rank = g.usize_in(8, 64);
        let growth = g.f64_in(1.1, 2.5);
        let r_tight = next_rank(&head, current, tight, min_rank, max_rank, growth);
        let r_loose = next_rank(&head, current, loose, min_rank, max_rank, growth);
        ensure(
            r_tight >= r_loose,
            format!(
                "target {tight:.4} chose rank {r_tight} < rank {r_loose} of looser \
                 target {loose:.4} (current {current}, |head| {})",
                head.len()
            ),
        )
    });
}

/// The stale pipeline still preconditions with *some* published factor
/// while newer ones build: versions only ever move forward.
#[test]
fn published_versions_monotone_under_staleness() {
    check("pipeline-version-monotone", 6, |g| {
        let dims = [(10usize, 10usize)];
        let mut opt =
            KfacOptimizer::new(Arc::new(decomposition::Srevd), quick_sched(5, 2), &dims, 5);
        opt.attach_pipeline(PipelineConfig {
            enabled: true,
            workers: 1,
            max_stale_steps: g.usize_in(1, 4),
            ..Default::default()
        });
        let mut last: Vec<Option<u64>> = vec![None; 2];
        for _ in 0..10 {
            let (a, gm, grads) = synth_factors(g, &dims);
            let grad_refs: Vec<&Matrix> = grads.iter().collect();
            let _ = opt.step_with_factors(0, a, gm, &grad_refs);
            let now = opt.pipeline().unwrap().published_versions();
            for (slot, (prev, cur)) in last.iter().zip(now.iter()).enumerate() {
                if let (Some(p), Some(c)) = (prev, cur) {
                    ensure(c >= p, format!("slot {slot}: version moved backwards {p} -> {c}"))?;
                }
            }
            last = now;
        }
        Ok(())
    });
}
