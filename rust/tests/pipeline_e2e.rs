//! End-to-end pipeline integration: config → trainer → solver → metrics,
//! over both engines, including PJRT-vs-native cross-checks.
//! Requires `make artifacts` (like runtime_integration.rs).

use rkfac::coordinator::config::{DataChoice, EngineChoice, ModelChoice, TrainConfig};
use rkfac::coordinator::{checkpoint, trainer};
use rkfac::nn::models;

/// PJRT tests need the compiled artifacts (`make artifacts`, Python/JAX
/// toolchain) and the real `xla` crate; offline checkouts have neither, so
/// those tests self-skip instead of failing the tier-1 run.
fn artifacts_ready() -> bool {
    let ok = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists();
    if !ok {
        eprintln!("skipping: artifacts/manifest.json not found (run `make artifacts`)");
    }
    ok
}

fn pjrt_tiny_cfg(solver: &str) -> TrainConfig {
    // The `tiny` artifact: widths [64, 32, 10], batch 16 → 1×8×8 images.
    TrainConfig {
        solver: solver.into(),
        epochs: 2,
        batch: 16,
        seed: 11,
        model: ModelChoice::Mlp { widths: vec![64, 32, 10] },
        data: DataChoice::Synthetic { n_train: 320, n_test: 64, height: 8, width: 8, channels: 1 },
        engine: EngineChoice::Pjrt { config: "tiny".into() },
        targets: vec![0.3],
        augment: false,
        out_dir: "/tmp/rkfac_e2e".into(),
        sched_width: 0,
        ..Default::default()
    }
}

#[test]
fn pjrt_training_runs_and_descends() {
    if !artifacts_ready() {
        return;
    }
    let cfg = pjrt_tiny_cfg("rs-kfac");
    let r = trainer::run(&cfg).expect("pjrt run failed (run `make artifacts`?)");
    assert_eq!(r.records.len(), 2);
    let first = &r.records[0];
    let last = r.records.last().unwrap();
    assert!(last.test_loss.is_finite());
    assert!(
        last.test_loss < 2.302 || last.test_acc > 0.15,
        "no learning: loss {} acc {}",
        last.test_loss,
        last.test_acc
    );
    assert!(first.train_loss > last.train_loss * 0.5, "train loss should drop");
}

#[test]
fn pjrt_and_native_engines_agree_early() {
    if !artifacts_ready() {
        return;
    }
    // Same data/seed/solver; both engines should produce very similar
    // first-epoch training losses (f32 vs f64 and schedule identical).
    let pjrt_cfg = pjrt_tiny_cfg("rs-kfac");
    let mut native_cfg = pjrt_cfg.clone();
    native_cfg.engine = EngineChoice::Native;
    let rp = trainer::run(&pjrt_cfg).expect("pjrt run");
    let rn = trainer::run(&native_cfg).expect("native run");
    let lp = rp.records[0].train_loss;
    let ln = rn.records[0].train_loss;
    // Different init RNG streams → not bit-equal; but both start at ~ln(10)
    // and must land in the same regime after one epoch.
    assert!(
        (lp - ln).abs() < 0.5 * ln.max(0.2),
        "engines diverge: pjrt {lp} vs native {ln}"
    );
}

#[test]
fn all_solvers_run_one_epoch_native() {
    for solver in
        ["kfac", "rs-kfac", "sre-kfac", "trunc-kfac", "nys-kfac", "ekfac", "rs-ekfac", "seng", "sgd"]
    {
        let mut cfg = pjrt_tiny_cfg(solver);
        cfg.engine = EngineChoice::Native;
        cfg.epochs = 1;
        let r = trainer::run(&cfg).unwrap_or_else(|e| panic!("{solver}: {e:#}"));
        assert!(r.records[0].test_loss.is_finite(), "{solver} diverged");
    }
}

#[test]
fn config_file_roundtrip_drives_trainer() {
    let toml = r#"
[train]
solver = "sgd"
epochs = 1
batch = 16
seed = 3
targets = [0.2]
out_dir = "/tmp/rkfac_e2e_cfg"

[model]
kind = "mlp"
widths = [48, 16, 10]

[data]
kind = "synthetic"
n_train = 160
n_test = 32
height = 4
width = 4
"#;
    let cfg = TrainConfig::from_toml(toml).unwrap();
    let r = trainer::run(&cfg).unwrap();
    assert_eq!(r.solver, "sgd");
    assert_eq!(r.records.len(), 1);
    // CSV output works end-to-end.
    r.write_csv("/tmp/rkfac_e2e_cfg/out.csv").unwrap();
    let text = std::fs::read_to_string("/tmp/rkfac_e2e_cfg/out.csv").unwrap();
    assert!(text.lines().count() == 2);
    std::fs::remove_dir_all("/tmp/rkfac_e2e_cfg").ok();
}

#[test]
fn checkpoint_resume_preserves_eval() {
    let mut net = models::mlp(&[48, 16, 10], 5);
    let (train, test) = trainer::load_data(&TrainConfig {
        data: DataChoice::Synthetic { n_train: 160, n_test: 48, height: 4, width: 4, channels: 3 },
        ..pjrt_tiny_cfg("sgd")
    })
    .unwrap();
    let _ = &train;
    let (l0, a0) = trainer::evaluate_native(&mut net, &test, 16);
    let path = "/tmp/rkfac_e2e_ckpt.bin";
    checkpoint::save(&net, path).unwrap();
    let mut net2 = models::mlp(&[48, 16, 10], 999); // different init
    checkpoint::load(&mut net2, path).unwrap();
    let (l1, a1) = trainer::evaluate_native(&mut net2, &test, 16);
    assert!((l0 - l1).abs() < 1e-12, "{l0} vs {l1}");
    assert_eq!(a0, a1);
    std::fs::remove_file(path).ok();
}

#[test]
fn vgg_native_one_step_smoke() {
    let cfg = TrainConfig {
        solver: "rs-kfac".into(),
        epochs: 1,
        batch: 8,
        seed: 4,
        model: ModelChoice::Vgg16Bn { scale_div: 64 },
        data: DataChoice::Synthetic { n_train: 16, n_test: 8, height: 32, width: 32, channels: 3 },
        engine: EngineChoice::Native,
        targets: vec![],
        augment: true,
        out_dir: "/tmp/rkfac_e2e".into(),
        sched_width: 0,
        ..Default::default()
    };
    let r = trainer::run(&cfg).unwrap();
    assert!(r.records[0].train_loss.is_finite());
}
