//! Backend-equivalence suite for the pluggable `[linalg]` compute backend.
//!
//! The threaded backend's contract (see `linalg::backend` module docs and
//! docs/linalg.md) is *bitwise* identity with the reference kernels at any
//! thread count: threads only redistribute disjoint output tiles, never any
//! per-element f64 accumulation order. These tests pin that contract for
//! every kernel on the trait (gemm family, syrk, ea-gram), the Householder
//! QR's threaded trailing update, the batched small-EVD, and an end-to-end
//! RSVD — across thread counts {1, 2, 4, 7} plus whatever
//! `RKFAC_LINALG_THREADS` the CI matrix injects, and across shapes both
//! large enough to engage the worker pool (work >= PAR_MIN_WORK) and odd
//! little remainders that stress the partition arithmetic.
//!
//! Mixed precision is NOT bitwise-equal to f64 (that is the point); for it
//! we pin the weaker guarantee — deterministic in the thread count, and
//! within f32-roundoff distance of the f64 result.
//!
//! Every test installs its backend through `backend::scoped`, which holds
//! the process-global install lock so concurrent tests in this binary
//! cannot race the selection.

use rkfac::linalg::backend::{self, BackendKind, Precision};
use rkfac::linalg::{evd, gemm, qr, Matrix, Pcg64};
use rkfac::rnla::rsvd::rsvd;
use rkfac::rnla::sketch::SketchConfig;

/// Thread counts to sweep: fixed odd mix + the CI matrix's env override.
fn thread_counts() -> Vec<usize> {
    let mut counts = vec![1, 2, 4, 7];
    if let Some(n) = std::env::var("RKFAC_LINALG_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
    {
        if n > 0 && !counts.contains(&n) {
            counts.push(n);
        }
    }
    counts
}

fn assert_bits_eq(a: &Matrix, b: &Matrix, what: &str) {
    assert!(a.shape() == b.shape(), "{what}: shape mismatch");
    for (i, (x, y)) in a.as_slice().iter().zip(b.as_slice()).enumerate() {
        assert!(
            x.to_bits() == y.to_bits(),
            "{what}: element {i} differs bitwise: {x:e} vs {y:e}"
        );
    }
}

fn assert_vec_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert!(a.len() == b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            x.to_bits() == y.to_bits(),
            "{what}: element {i} differs bitwise: {x:e} vs {y:e}"
        );
    }
}

/// (m, k, n) GEMM shapes: one large enough that the threaded backend
/// actually engages its worker pool (2·123·301·57 ≈ 4.2M flops >
/// PAR_MIN_WORK), the rest odd/degenerate shapes that stress remainder
/// handling in the row partition and the 1×4 microkernel tail.
const GEMM_SHAPES: &[(usize, usize, usize)] =
    &[(123, 301, 57), (1, 1, 1), (2, 3, 1), (5, 7, 3), (17, 19, 23)];

#[test]
fn threaded_gemm_family_bitwise_equal_across_thread_counts() {
    let mut rng = Pcg64::new(7);
    for &(m, k, n) in GEMM_SHAPES {
        let a = rng.gaussian_matrix(m, k);
        let b = rng.gaussian_matrix(k, n);
        let at = a.transpose(); // k×m operand for matmul_tn
        let bt = b.transpose(); // n×k operand for matmul_nt
        let c0 = rng.gaussian_matrix(m, n);

        let (mm_ref, acc_ref, tn_ref, nt_ref) = {
            let _g = backend::scoped(BackendKind::Reference, 1, Precision::F64);
            let mut c = c0.clone();
            gemm::gemm_acc(&mut c, 1.25, &a, &b);
            (gemm::matmul(&a, &b), c, gemm::matmul_tn(&at, &b), gemm::matmul_nt(&a, &bt))
        };

        for t in thread_counts() {
            let _g = backend::scoped(BackendKind::Threaded, t, Precision::F64);
            let what = format!("{m}x{k}x{n} t={t}");
            assert_bits_eq(&gemm::matmul(&a, &b), &mm_ref, &format!("matmul {what}"));
            let mut c = c0.clone();
            gemm::gemm_acc(&mut c, 1.25, &a, &b);
            assert_bits_eq(&c, &acc_ref, &format!("gemm_acc {what}"));
            assert_bits_eq(&gemm::matmul_tn(&at, &b), &tn_ref, &format!("matmul_tn {what}"));
            assert_bits_eq(&gemm::matmul_nt(&a, &bt), &nt_ref, &format!("matmul_nt {what}"));
        }
    }
}

#[test]
fn threaded_syrk_and_ea_gram_bitwise_equal_across_thread_counts() {
    let mut rng = Pcg64::new(11);
    // (d, cols): 89²·301 ≈ 2.4M engages the pool; the rest are remainders.
    for &(d, cols) in &[(89usize, 301usize), (1, 1), (5, 7), (17, 3)] {
        let m = rng.gaussian_matrix(d, cols);
        let dst0 = {
            // A symmetric starting accumulator, as the EA update maintains.
            let s = rng.gaussian_matrix(d, cols + 1);
            gemm::syrk(&s)
        };

        let (syrk_ref, ea_ref) = {
            let _g = backend::scoped(BackendKind::Reference, 1, Precision::F64);
            let mut dst = dst0.clone();
            gemm::ea_gram_update(&mut dst, 0.9, &m, cols as f64);
            (gemm::syrk(&m), dst)
        };

        for t in thread_counts() {
            let _g = backend::scoped(BackendKind::Threaded, t, Precision::F64);
            let what = format!("d={d} cols={cols} t={t}");
            assert_bits_eq(&gemm::syrk(&m), &syrk_ref, &format!("syrk {what}"));
            let mut dst = dst0.clone();
            gemm::ea_gram_update(&mut dst, 0.9, &m, cols as f64);
            assert_bits_eq(&dst, &ea_ref, &format!("ea_gram_update {what}"));
        }
    }
}

#[test]
fn threaded_qr_bitwise_equal_across_thread_counts() {
    let mut rng = Pcg64::new(13);
    // 3000×180: each early reflector's trailing update is ~4·179·3000 ≈
    // 2.1M flops, so the per-reflector fan-out engages; 53×17 stays on the
    // sequential path (work below threshold) and must be identical too.
    for &(m, n) in &[(3000usize, 180usize), (53, 17)] {
        let a = rng.gaussian_matrix(m, n);

        let fac_ref = {
            let _g = backend::scoped(BackendKind::Reference, 1, Precision::F64);
            qr::thin_qr(&a)
        };

        for t in thread_counts() {
            let _g = backend::scoped(BackendKind::Threaded, t, Precision::F64);
            let fac = qr::thin_qr(&a);
            let what = format!("{m}x{n} t={t}");
            assert_bits_eq(&fac.q, &fac_ref.q, &format!("qr.q {what}"));
            assert_bits_eq(&fac.r, &fac_ref.r, &format!("qr.r {what}"));
        }
    }
}

#[test]
fn threaded_evd_batch_bitwise_equal_across_thread_counts() {
    let mut rng = Pcg64::new(17);
    // d=64 puts the batch over the work threshold (8·64³ ≈ 2.1M); the rest
    // exercise the per-matrix partition (more threads than matrices, d=1).
    let mats: Vec<Matrix> = [64usize, 33, 1, 17]
        .iter()
        .map(|&d| {
            let g = rng.gaussian_matrix(d, d + 3);
            gemm::syrk(&g)
        })
        .collect();
    let refs: Vec<&Matrix> = mats.iter().collect();

    let evds_ref = {
        let _g = backend::scoped(BackendKind::Reference, 1, Precision::F64);
        evd::sym_evd_batch(&refs)
    };

    for t in thread_counts() {
        let _g = backend::scoped(BackendKind::Threaded, t, Precision::F64);
        let evds = evd::sym_evd_batch(&refs);
        assert!(evds.len() == evds_ref.len());
        for (i, (e, r)) in evds.iter().zip(&evds_ref).enumerate() {
            assert_bits_eq(&e.u, &r.u, &format!("evd[{i}].u t={t}"));
            assert_vec_bits_eq(&e.lambda, &r.lambda, &format!("evd[{i}].lambda t={t}"));
        }
    }
}

#[test]
fn threaded_rsvd_end_to_end_bitwise_equal() {
    // End-to-end through the range finder (3 sketch GEMMs + thin QR) and
    // the small SVD: same seed, any backend/thread count → identical bits.
    // 400×400 at subspace 26 puts the range-finder GEMMs at ~8.3M flops.
    let x = {
        let mut rng = Pcg64::new(19);
        let g = rng.gaussian_matrix(400, 400);
        gemm::syrk(&g) // symmetric PSD, like a K-factor
    };
    let cfg = SketchConfig::new(20, 6, 2);

    let fac_ref = {
        let _g = backend::scoped(BackendKind::Reference, 1, Precision::F64);
        rsvd(&x, &cfg, &mut Pcg64::new(23))
    };

    for t in [2usize, 4] {
        let _g = backend::scoped(BackendKind::Threaded, t, Precision::F64);
        let fac = rsvd(&x, &cfg, &mut Pcg64::new(23));
        assert_bits_eq(&fac.u, &fac_ref.u, &format!("rsvd.u t={t}"));
        assert_bits_eq(&fac.v, &fac_ref.v, &format!("rsvd.v t={t}"));
        assert_vec_bits_eq(&fac.sigma, &fac_ref.sigma, &format!("rsvd.sigma t={t}"));
    }
}

#[test]
fn mixed_precision_deterministic_in_thread_count_and_close_to_f64() {
    let mut rng = Pcg64::new(29);
    let a = rng.gaussian_matrix(123, 301);
    let b = rng.gaussian_matrix(301, 57);
    let p = rng.gaussian_matrix(301, 123); // k×m operand for the tn path

    let (exact, exact_tn) = {
        let _g = backend::scoped(BackendKind::Threaded, 4, Precision::F64);
        (backend::sketch_matmul(&a, &b), backend::sketch_matmul_tn(&p, &b))
    };

    let (base, base_tn) = {
        let _g = backend::scoped(BackendKind::Threaded, 1, Precision::Mixed);
        (backend::sketch_matmul(&a, &b), backend::sketch_matmul_tn(&p, &b))
    };

    // Deterministic in the thread count: the mixed kernels use the same
    // disjoint row partition, so redistribution never reorders any
    // element's accumulation chain.
    for t in [2usize, 4, 9] {
        let _g = backend::scoped(BackendKind::Threaded, t, Precision::Mixed);
        assert_bits_eq(&backend::sketch_matmul(&a, &b), &base, &format!("mixed matmul t={t}"));
        assert_bits_eq(
            &backend::sketch_matmul_tn(&p, &b),
            &base_tn,
            &format!("mixed matmul_tn t={t}"),
        );
    }

    // Tolerance-bounded agreement with f64: operands are demoted to f32
    // once (relative error ~1e-7 each) and accumulated in f64, so the
    // result sits well inside 1e-5 relative error for these sizes.
    for (mixed, full, what) in [(&base, &exact, "matmul"), (&base_tn, &exact_tn, "matmul_tn")] {
        let mut diff = mixed.clone();
        diff.axpy(-1.0, full);
        let rel = diff.fro_norm() / full.fro_norm();
        assert!(rel < 1e-5, "mixed {what}: relative error {rel:e} vs f64");
    }
    // And it is genuinely different arithmetic, not silently f64.
    assert!(
        base.as_slice().iter().zip(exact.as_slice()).any(|(x, y)| x != y),
        "mixed matmul should differ from f64 in low bits"
    );
}

#[test]
fn install_from_env_returns_resolved_selection() {
    // Assert on the *returned* selection, not on `backend::current()`: the
    // return value is computed under the install lock, so this holds even
    // if another test in this binary reinstalls concurrently.
    let sel = backend::install_from_env();
    assert!(sel.threads >= 1, "auto threads must resolve to >= 1");
    match std::env::var("RKFAC_LINALG_BACKEND").ok().as_deref() {
        Some("threaded") => assert!(sel.kind == BackendKind::Threaded),
        _ => assert!(sel.kind == BackendKind::Reference), // default + fallback
    }
    if let Some(t) = std::env::var("RKFAC_LINALG_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&t| t > 0)
    {
        assert!(sel.threads == t, "explicit thread count must pass through");
    }
    match std::env::var("RKFAC_LINALG_PRECISION").ok().as_deref() {
        Some("mixed") => assert!(sel.precision == Precision::Mixed),
        _ => assert!(sel.precision == Precision::F64),
    }
    // Leave the process-global selection at the defaults for other suites.
    backend::install(BackendKind::Reference, 1, Precision::F64);
}
