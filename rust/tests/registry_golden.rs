//! Golden-equivalence suite for the solver registry.
//!
//! The pre-refactor `Solver::by_name` enum path constructed the concrete
//! optimizers directly (`KfacOptimizer::new(<strategy>, …)`,
//! `EkfacOptimizer::new(…)`, `SengOptimizer::new(SengConfig::default(), …)`,
//! `SgdOptimizer::new(SgdConfig::default(), …)`). These tests pin the new
//! [`SolverRegistry`] path to that behaviour: every legacy solver name
//! built through the registry must produce **bitwise-identical** step
//! deltas to direct construction, on a fixed seed, 2 Kronecker blocks, and
//! 3 step rounds — so the registry/trait indirection is proven to be pure
//! plumbing.
//!
//! Also covered: canonical `family+strategy` specs alias the legacy names
//! bitwise, a third-party [`Decomposition`] registers and trains without
//! touching core files, and the async pipeline attached through the trait
//! at `max_stale_steps = 0` stays bitwise-synchronous end to end.

use std::sync::Arc;

use rkfac::linalg::evd;
use rkfac::linalg::{Matrix, Pcg64};
use rkfac::nn::models;
use rkfac::optim::schedules::{KfacSchedules, StepSchedule};
use rkfac::optim::{
    build_solver, EkfacOptimizer, KfacOptimizer, LEGACY_SOLVER_NAMES, Preconditioner, SengConfig,
    SengOptimizer, SgdConfig, SgdOptimizer, SolverRegistry,
};
use rkfac::pipeline::PipelineConfig;
use rkfac::rnla::decomposition::{Exact, ExactTruncated, Nystrom, Rsvd, Srevd};
use rkfac::rnla::{DecompMeta, Decomposition, LowRankFactor, SketchConfig};

/// Fast deterministic schedules for the golden runs.
fn golden_sched() -> KfacSchedules {
    KfacSchedules {
        rho: 0.9,
        t_ku: 1,
        t_ki: StepSchedule::constant(2.0),
        lambda: StepSchedule::constant(0.1),
        alpha: StepSchedule::constant(0.2),
        rank: StepSchedule::constant(6.0),
        oversample: StepSchedule::constant(4.0),
        n_power_iter: 2,
        weight_decay: 0.0,
    }
}

/// The reference constructions — exactly what the old enum arms did.
fn reference_solver(name: &str, dims: &[(usize, usize)], seed: u64) -> Box<dyn Preconditioner> {
    let sched = golden_sched();
    match name {
        "kfac" => Box::new(KfacOptimizer::new(Arc::new(Exact), sched, dims, seed)),
        "rs-kfac" => Box::new(KfacOptimizer::new(Arc::new(Rsvd), sched, dims, seed)),
        "sre-kfac" => Box::new(KfacOptimizer::new(Arc::new(Srevd), sched, dims, seed)),
        "trunc-kfac" => Box::new(KfacOptimizer::new(Arc::new(ExactTruncated), sched, dims, seed)),
        "nys-kfac" => Box::new(KfacOptimizer::new(Arc::new(Nystrom), sched, dims, seed)),
        "ekfac" => Box::new(EkfacOptimizer::new(Arc::new(Exact), sched, dims, seed)),
        "rs-ekfac" => Box::new(EkfacOptimizer::new(Arc::new(Rsvd), sched, dims, seed)),
        "sre-ekfac" => Box::new(EkfacOptimizer::new(Arc::new(Srevd), sched, dims, seed)),
        "nys-ekfac" => Box::new(EkfacOptimizer::new(Arc::new(Nystrom), sched, dims, seed)),
        "seng" => Box::new(SengOptimizer::new(SengConfig::default(), dims.len(), seed)),
        "sgd" => Box::new(SgdOptimizer::new(SgdConfig::default(), dims.len())),
        other => panic!("no reference construction for '{other}'"),
    }
}

/// Drive two solvers over the same 3-round trajectory (fixed seed, 2
/// blocks) and require bitwise-equal deltas at every step.
fn assert_bitwise_equal_runs(
    label: &str,
    mut a: Box<dyn Preconditioner>,
    mut b: Box<dyn Preconditioner>,
) {
    // [12, 8, 10] MLP → 2 Kronecker blocks.
    let mut net = models::mlp(&[12, 8, 10], 77);
    let mut rng = Pcg64::new(78);
    for round in 0..3 {
        let x = rng.gaussian_matrix(12, 8);
        let labels: Vec<usize> = (0..8).map(|i| i % 10).collect();
        net.train_batch(&x, &labels, true);
        let caps = net.kfac_captures();
        let da = a.step(0, &caps);
        let db = b.step(0, &caps);
        assert_eq!(da.len(), 2, "{label}: block count");
        for (bi, (x1, x2)) in da.iter().zip(db.iter()).enumerate() {
            assert_eq!(
                x1.as_slice(),
                x2.as_slice(),
                "{label}: round {round} block {bi} deltas differ"
            );
        }
        // Advance the trajectory with the reference deltas.
        let (lr, wd) = a.lr_wd(0);
        net.apply_steps(&da, lr, wd);
    }
}

/// Every legacy name through the registry ≡ direct construction, bitwise.
#[test]
fn legacy_names_bitwise_match_direct_construction() {
    let dims = [(12usize, 8usize), (8, 10)];
    for name in LEGACY_SOLVER_NAMES {
        let reference = reference_solver(name, &dims, 5);
        let via_registry = build_solver(name, golden_sched(), &dims, 5).unwrap();
        assert_eq!(via_registry.name(), name);
        assert_bitwise_equal_runs(name, reference, via_registry);
    }
}

/// Canonical `family+strategy` specs are exact aliases of the legacy names.
#[test]
fn canonical_specs_bitwise_match_legacy_names() {
    let dims = [(12usize, 8usize), (8, 10)];
    for (canonical, legacy) in [
        ("kfac+exact", "kfac"),
        ("kfac+rsvd", "rs-kfac"),
        ("kfac+srevd", "sre-kfac"),
        ("kfac+trunc", "trunc-kfac"),
        ("kfac+nystrom", "nys-kfac"),
        ("ekfac+nystrom", "nys-ekfac"),
    ] {
        let a = build_solver(legacy, golden_sched(), &dims, 9).unwrap();
        let b = build_solver(canonical, golden_sched(), &dims, 9).unwrap();
        assert_eq!(b.name(), legacy, "{canonical} takes the legacy display name");
        assert_bitwise_equal_runs(canonical, a, b);
    }
}

/// A third-party decomposition: exact EVD truncated to half the dimension.
/// Registered — not patched into core files.
struct HalfRank;

impl Decomposition for HalfRank {
    fn key(&self) -> &str {
        "halfrank"
    }

    fn decompose(&self, m: &Matrix, _cfg: &SketchConfig, _rng: &mut Pcg64) -> LowRankFactor {
        let e = evd::sym_evd(m).truncate((m.rows() + 1) / 2);
        LowRankFactor::new(e.u, e.lambda)
    }

    fn meta(&self, dim: usize, _cfg: &SketchConfig) -> DecompMeta {
        DecompMeta {
            key: "halfrank".into(),
            flops: 9.0 * (dim as f64).powi(3),
            randomized: false,
            projection_sides: 0,
            backend: rkfac::linalg::backend::current(),
        }
    }
}

/// Registering a dummy third-party `Decomposition` makes `kfac+halfrank`
/// buildable and trainable through the standard registry path.
#[test]
fn third_party_decomposition_registers_and_trains() {
    let mut registry = SolverRegistry::with_defaults();
    registry.register_decomposition(Arc::new(HalfRank));
    let dims = [(12usize, 8usize), (8, 10)];
    let mut solver = registry.build("kfac+halfrank", golden_sched(), &dims, 11).unwrap();
    assert_eq!(solver.name(), "kfac+halfrank");

    let mut net = models::mlp(&[12, 8, 10], 12);
    let mut rng = Pcg64::new(13);
    for _ in 0..3 {
        let x = rng.gaussian_matrix(12, 8);
        let labels: Vec<usize> = (0..8).map(|i| i % 10).collect();
        net.train_batch(&x, &labels, true);
        let caps = net.kfac_captures();
        let deltas = solver.step(0, &caps);
        for d in &deltas {
            assert!(d.as_slice().iter().all(|v| v.is_finite()));
        }
        let (lr, wd) = solver.lr_wd(0);
        net.apply_steps(&deltas, lr, wd);
    }
    // The half-dimension truncation shows up in the installed ranks.
    let ranks = solver.diagnostics().block_ranks;
    assert_eq!(ranks, vec![(6, 4), (4, 5)]);
    // The default registry must not know the key (no global state).
    assert!(build_solver("kfac+halfrank", golden_sched(), &dims, 11).is_err());
}

/// The async pipeline attached through the trait, at `max_stale_steps = 0`,
/// stays bitwise-synchronous against the inline registry path.
#[test]
fn pipeline_through_registry_zero_staleness_bitwise() {
    let dims = [(12usize, 8usize), (8, 10)];
    let inline = build_solver("rs-kfac", golden_sched(), &dims, 21).unwrap();
    let mut piped = build_solver("rs-kfac", golden_sched(), &dims, 21).unwrap();
    assert!(piped.attach_pipeline(&PipelineConfig {
        enabled: true,
        workers: 2,
        max_stale_steps: 0,
        ..Default::default()
    }));
    assert_bitwise_equal_runs("rs-kfac+pipeline@0", inline, piped);
}
