//! Acceptance suite for crash-safe checkpointing + `Session::resume`.
//!
//! The headline pin: a run interrupted at epoch *k* (checkpointing every
//! epoch) and resumed from `ckpt_..._e<k>.bin` reproduces the
//! uninterrupted run's remaining metrics, rank traces and pipeline traces
//! **bitwise** — for `kfac+rsvd` and `ekfac+rsvd`, native and pipelined at
//! `max_stale_steps = 0`. Plus the failure modes: truncated / garbage /
//! wrong-solver checkpoints fail loudly, v1 files downgrade to params-only
//! with a warning, and the `--resume` flag round-trips through the CLI
//! layer the `rkfac train` binary uses.

use anyhow::Result;

use rkfac::coordinator::checkpoint;
use rkfac::coordinator::experiment::{ExperimentBuilder, ExperimentSpec};
use rkfac::coordinator::hooks::{CheckpointHook, EpochCtx, HookAction, RunHook};
use rkfac::coordinator::metrics::RunResult;
use rkfac::util::cli::Args;

/// The shared tiny workload: 2 Kronecker blocks, synthetic data, 4 epochs.
const TINY_TOML: &str = r#"
[model]
kind = "mlp"
widths = [108, 32, 10]

[data]
kind = "synthetic"
n_train = 320
n_test = 96
height = 6
width = 6

[train]
epochs = 4
batch = 32
seed = 0
targets = [0.5]
out_dir = "/tmp/rkfac_resume_suite"
"#;

fn spec_for(solver: &str, pipelined: bool) -> ExperimentSpec {
    let mut b = ExperimentBuilder::new().toml_str(TINY_TOML).unwrap().solver(solver);
    if pipelined {
        b = b
            .set("pipeline.enabled", "true")
            .set("pipeline.workers", "2")
            .set("pipeline.max_stale_steps", "0");
    }
    b.build().unwrap()
}

fn ckpt_dir(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("rkfac_resume_{tag}_{}", std::process::id()))
}

/// Deterministic interrupt: vote Stop at the end of epoch `.0`, so the
/// "crashed" run always cuts at a known epoch boundary (an accuracy-based
/// stop would move with the trajectory).
struct StopAfterEpoch(usize);

impl RunHook for StopAfterEpoch {
    fn name(&self) -> &str {
        "stop-after"
    }

    fn on_epoch_end(&mut self, ctx: &EpochCtx<'_>) -> Result<HookAction> {
        Ok(if ctx.epoch >= self.0 { HookAction::Stop } else { HookAction::Continue })
    }
}

type PipeKey = (usize, usize, usize, usize, usize, usize, Option<u64>);

/// The timing-independent fields of one pipeline-telemetry row (the
/// queue-depth high-water marks vary with worker timing even between two
/// identical uninterrupted runs, so they are not part of the golden).
fn pipe_key(t: &rkfac::coordinator::metrics::PipeTraceRow) -> PipeKey {
    let stale = t.max_staleness;
    (t.round, t.epoch, t.step, t.recovered_jobs, t.superseded_jobs, t.warming_slots, stale)
}

fn assert_record_bitwise(a: &RunResult, b_records: &[rkfac::coordinator::EpochRecord]) {
    assert_eq!(a.records.len(), b_records.len());
    for (ra, rb) in a.records.iter().zip(b_records.iter()) {
        assert_eq!(ra.epoch, rb.epoch);
        assert_eq!(ra.train_loss, rb.train_loss, "epoch {}", ra.epoch);
        assert_eq!(ra.test_loss, rb.test_loss, "epoch {}", ra.epoch);
        assert_eq!(ra.test_acc, rb.test_acc, "epoch {}", ra.epoch);
    }
}

/// Interrupt at epoch `k`, resume from the epoch-`k` checkpoint, and pin
/// the continuation bitwise against the uninterrupted run.
fn run_interrupt_resume_golden(solver: &str, pipelined: bool, tag: &str) {
    let k = 1; // checkpoint boundary: epochs 0..=1 run, 2..=3 resume
    let dir = ckpt_dir(tag);
    let full = spec_for(solver, pipelined).session().run().unwrap();
    assert_eq!(full.records.len(), 4);

    let mut first = spec_for(solver, pipelined).session();
    first.add_hook(Box::new(CheckpointHook::new(dir.to_str().unwrap(), 1)));
    first.add_hook(Box::new(StopAfterEpoch(k)));
    let partial = first.run().unwrap();
    assert_eq!(partial.records.len(), k + 1);
    // The interruption must not have perturbed the prefix.
    assert_record_bitwise(&partial, &full.records[..k + 1]);

    let ckpt = checkpoint::epoch_path(&dir, solver, 0, k);
    assert!(ckpt.exists(), "CheckpointHook must have written {}", ckpt.display());
    let resumed = spec_for(solver, pipelined).session().resume(&ckpt).unwrap();

    // Metrics: the resumed segment is bitwise the uninterrupted tail.
    assert_record_bitwise(&resumed, &full.records[k + 1..]);
    // Wall clock continues from the checkpoint instead of restarting.
    assert!(
        resumed.records[0].wall_s >= partial.records.last().unwrap().wall_s,
        "{solver}/{tag}: resumed wall_s must continue the interrupted run's"
    );

    // Rank traces: the resumed rows are exactly the full run's rows from
    // the first post-checkpoint refresh round on (absolute rounds, epochs
    // and steps — the restored counters position everything).
    let boundary_round = partial.rank_trace.iter().map(|t| t.round).max().map_or(0, |r| r + 1);
    let full_tail: Vec<_> = full
        .rank_trace
        .iter()
        .filter(|t| t.round >= boundary_round)
        .map(|t| (t.round, t.epoch, t.step, t.block, t.rank_a, t.rank_g))
        .collect();
    let resumed_rows: Vec<_> = resumed
        .rank_trace
        .iter()
        .map(|t| (t.round, t.epoch, t.step, t.block, t.rank_a, t.rank_g))
        .collect();
    assert_eq!(resumed_rows, full_tail, "{solver}/{tag}: rank traces must continue bitwise");

    // Pipeline traces (deterministic fields; queue-depth high-water marks
    // depend on worker timing even between two identical runs).
    if pipelined {
        assert!(!full.pipe_trace.is_empty());
        let full_tail: Vec<PipeKey> = full
            .pipe_trace
            .iter()
            .filter(|t| t.round >= boundary_round)
            .map(pipe_key)
            .collect();
        let resumed_rows: Vec<PipeKey> = resumed.pipe_trace.iter().map(pipe_key).collect();
        assert_eq!(resumed_rows, full_tail, "{solver}/{tag}: pipe traces must continue bitwise");
    }

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn kfac_rsvd_native_resume_bitwise() {
    run_interrupt_resume_golden("kfac+rsvd", false, "kfac_native");
}

#[test]
fn ekfac_rsvd_native_resume_bitwise() {
    run_interrupt_resume_golden("ekfac+rsvd", false, "ekfac_native");
}

#[test]
fn kfac_rsvd_pipelined_stale0_resume_bitwise() {
    run_interrupt_resume_golden("kfac+rsvd", true, "kfac_pipe");
}

#[test]
fn ekfac_rsvd_pipelined_stale0_resume_bitwise() {
    run_interrupt_resume_golden("ekfac+rsvd", true, "ekfac_pipe");
}

/// SGD's momentum buffers ride the same checkpoint subsystem.
#[test]
fn sgd_resume_bitwise() {
    run_interrupt_resume_golden("sgd", false, "sgd_native");
}

/// Failure modes: truncated, garbage, wrong-solver and wrong-model
/// checkpoints all fail loudly; a v1 params-only file downgrades with a
/// restart instead of silently pretending to resume.
#[test]
fn corrupt_and_legacy_checkpoint_handling() {
    let dir = ckpt_dir("corrupt");
    std::fs::create_dir_all(&dir).unwrap();

    // Garbage file: clear error.
    let garbage = dir.join("garbage.bin");
    std::fs::write(&garbage, b"definitely not a checkpoint").unwrap();
    let err =
        spec_for("kfac+rsvd", false).session().resume(&garbage).unwrap_err().to_string();
    assert!(err.contains("not a rkfac checkpoint"), "{err}");

    // A real checkpoint, truncated: clear error, nothing trained.
    let mut first = spec_for("kfac+rsvd", false).session();
    first.add_hook(Box::new(CheckpointHook::new(dir.to_str().unwrap(), 1)));
    first.add_hook(Box::new(StopAfterEpoch(0)));
    first.run().unwrap();
    let ckpt = checkpoint::epoch_path(&dir, "kfac+rsvd", 0, 0);
    let good = std::fs::read(&ckpt).unwrap();
    let truncated = dir.join("truncated.bin");
    std::fs::write(&truncated, &good[..good.len() / 2]).unwrap();
    assert!(spec_for("kfac+rsvd", false).session().resume(&truncated).is_err());

    // Trailing garbage after a valid v2 body: rejected, not prefix-loaded.
    let trailing = dir.join("trailing.bin");
    let mut bad = good.clone();
    bad.extend_from_slice(b"JUNK");
    std::fs::write(&trailing, &bad).unwrap();
    let err =
        spec_for("kfac+rsvd", false).session().resume(&trailing).unwrap_err().to_string();
    assert!(err.contains("trailing garbage"), "{err}");

    // Wrong solver for the checkpoint: the embedded strategy key refuses.
    let err = spec_for("kfac+srevd", false).session().resume(&ckpt).unwrap_err().to_string();
    assert!(err.contains("restoring solver state"), "{err}");

    // Seed mismatch: every restored RNG stream is a position within the
    // original seed's streams, so resuming under another seed refuses.
    let reseeded = ExperimentBuilder::new()
        .toml_str(TINY_TOML)
        .unwrap()
        .solver("kfac+rsvd")
        .set("train.seed", "7")
        .build()
        .unwrap();
    let err = reseeded.session().resume(&ckpt).unwrap_err().to_string();
    assert!(err.contains("seed 0") && err.contains("seed 7"), "{err}");

    // A checkpoint at the end of the schedule refuses instead of
    // "succeeding" with zero epochs trained.
    let done = ExperimentBuilder::new()
        .toml_str(TINY_TOML)
        .unwrap()
        .solver("kfac+rsvd")
        .set("train.epochs", "1")
        .build()
        .unwrap();
    let err = done.session().resume(&ckpt).unwrap_err().to_string();
    assert!(err.contains("already complete"), "{err}");

    // Wrong model shape: rejected before any state mutates.
    let other = ExperimentBuilder::new()
        .toml_str(TINY_TOML)
        .unwrap()
        .set("model.widths", "[108, 16, 10]")
        .solver("kfac+rsvd")
        .build()
        .unwrap();
    assert!(other.session().resume(&ckpt).is_err());

    std::fs::remove_dir_all(&dir).ok();
}

/// v1 (params-only) checkpoints still load: the run restarts from epoch 0
/// with the checkpointed weights and completes the configured schedule.
#[test]
fn v1_checkpoint_resumes_params_only() {
    let dir = ckpt_dir("v1");
    std::fs::create_dir_all(&dir).unwrap();
    // Produce a v1 file via the legacy params-only writer.
    let mut net = rkfac::nn::models::mlp(&[108, 32, 10], 0);
    let v1 = dir.join("legacy.bin");
    checkpoint::save(&net, &v1).unwrap();
    let r = spec_for("kfac+rsvd", false).session().resume(&v1).unwrap();
    assert_eq!(r.records.len(), 4, "params-only resume restarts the full schedule");
    assert!(r.records.last().unwrap().test_loss.is_finite());
    // v1 with trailing bytes is rejected (the byte-length validation).
    let mut bad = std::fs::read(&v1).unwrap();
    bad.push(0x42);
    std::fs::write(&v1, &bad).unwrap();
    assert!(checkpoint::load(&mut net, &v1).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

/// The `--resume` path through the CLI layer: flags lower through
/// `ExperimentBuilder::cli_args` exactly as `rkfac train` does, the
/// checkpoint-every hook writes during the first invocation, and a second
/// invocation with `--resume` continues bitwise.
#[test]
fn checkpoint_hook_and_resume_roundtrip_through_cli_layer() {
    let dir = ckpt_dir("cli");
    let parse = |s: &str| Args::parse(s.split_whitespace().map(String::from));
    let table = [("solver", "train.solver")];

    let full = spec_for("kfac+rsvd", false).session().run().unwrap();

    // First invocation: `rkfac train --solver kfac+rsvd --checkpoint-every 1`.
    let args = parse("train --solver kfac+rsvd --checkpoint-every 1");
    let spec = ExperimentBuilder::new()
        .toml_str(TINY_TOML)
        .unwrap()
        .cli_args(&args, &table)
        .unwrap()
        .build()
        .unwrap();
    let mut session = spec.session();
    let every: usize = args.get("checkpoint-every").unwrap().parse().unwrap();
    session.add_hook(Box::new(CheckpointHook::new(dir.to_str().unwrap(), every)));
    session.add_hook(Box::new(StopAfterEpoch(1)));
    let partial = session.run().unwrap();
    assert_eq!(partial.records.len(), 2);

    // Second invocation: `rkfac train --solver kfac+rsvd --resume <ckpt>`.
    let ckpt = checkpoint::epoch_path(&dir, "kfac+rsvd", 0, 1);
    let args = parse(&format!("train --solver kfac+rsvd --resume {}", ckpt.display()));
    let spec = ExperimentBuilder::new()
        .toml_str(TINY_TOML)
        .unwrap()
        .cli_args(&args, &table)
        .unwrap()
        .build()
        .unwrap();
    let resume_path = args.get("resume").expect("--resume lowers through the CLI layer");
    let resumed = spec.session().resume(resume_path).unwrap();
    assert_record_bitwise(&resumed, &full.records[2..]);

    std::fs::remove_dir_all(&dir).ok();
}
