//! Acceptance suite for the Experiment/Session/Sweep API.
//!
//! Pins the three contract points of the redesign:
//!
//! 1. **Layered overrides** — TOML < builder < `--set`, call-order
//!    independent, with validation errors citing the offending layer.
//! 2. **Golden bitwise equivalence** — a `Session` run of `kfac+rsvd`
//!    (seed 0, `[pipeline] max_stale_steps = 0`) is bitwise-identical to
//!    the legacy `trainer::run` shim, records and traces included; the
//!    shim is pure plumbing over the session.
//! 3. **Sweep aggregation** — one `{2 solvers × 2 seeds}` sweep reproduces
//!    exactly the `summarize` output that previously required N separate
//!    CLI runs, and yields one `SolverSummary` per solver.
//!
//! Plus the `[registry]` wiring end-to-end: a TOML experiment names an
//! out-of-tree decomposition through a registered extension and trains.

use std::sync::Arc;

use rkfac::coordinator::experiment::{ConfigLayer, ExperimentBuilder, ExperimentSpec};
use rkfac::coordinator::hooks::EarlyStopHook;
use rkfac::coordinator::{metrics, trainer, Sweep};
use rkfac::linalg::{evd, Matrix, Pcg64};
use rkfac::rnla::{DecompMeta, Decomposition, LowRankFactor, SketchConfig};

/// The shared tiny workload: 2 Kronecker blocks, synthetic data, 2 epochs.
const TINY_TOML: &str = r#"
[model]
kind = "mlp"
widths = [108, 32, 10]

[data]
kind = "synthetic"
n_train = 320
n_test = 96
height = 6
width = 6

[train]
solver = "kfac+rsvd"
epochs = 2
batch = 32
seed = 0
targets = [0.15, 0.3]
out_dir = "/tmp/rkfac_experiment_api"
"#;

fn tiny_spec() -> ExperimentSpec {
    ExperimentBuilder::new().toml_str(TINY_TOML).unwrap().build().unwrap()
}

// ---------------------------------------------------------------------------
// 1. Layered override precedence.
// ---------------------------------------------------------------------------

#[test]
fn layered_override_precedence_toml_builder_cli() {
    // TOML says 2 epochs / seed 0; builder raises epochs; --set wins.
    let spec = ExperimentBuilder::new()
        .toml_str(TINY_TOML)
        .unwrap()
        .epochs(5)
        .seed(7)
        .override_set("train.epochs=3")
        .unwrap()
        .build()
        .unwrap();
    assert_eq!(spec.cfg().epochs, 3, "--set > builder");
    assert_eq!(spec.cfg().seed, 7, "builder > TOML");
    assert_eq!(spec.cfg().batch, 32, "TOML survives unoverridden");
    assert_eq!(spec.layer_of("train.epochs"), Some(ConfigLayer::Cli));
    assert_eq!(spec.layer_of("train.seed"), Some(ConfigLayer::Builder));
    assert_eq!(spec.layer_of("train.batch"), Some(ConfigLayer::Toml));

    // Same layers, opposite call order — precedence must not change.
    let spec2 = ExperimentBuilder::new()
        .override_set("train.epochs=3")
        .unwrap()
        .epochs(5)
        .seed(7)
        .toml_str(TINY_TOML)
        .unwrap()
        .build()
        .unwrap();
    assert_eq!(spec2.cfg().epochs, 3);
    assert_eq!(spec2.cfg().seed, 7);

    // The resolved spec trains (precedence reached the actual run config).
    let r = spec.session().run().unwrap();
    assert_eq!(r.records.len(), 3);
    assert_eq!(r.seed, 7);
}

#[test]
fn validation_errors_cite_the_offending_layer() {
    let err = ExperimentBuilder::new()
        .toml_str(TINY_TOML)
        .unwrap()
        .override_set("train.batch=-8")
        .unwrap()
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("--set train.batch=-8"), "{err}");

    let err = ExperimentBuilder::new()
        .toml_str("[train]\nsolver = \"kfac+rsvdd\"\n")
        .unwrap()
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("TOML"), "{err}");
    assert!(err.contains("known specs"), "{err}");

    let err =
        ExperimentBuilder::new().set("pipeline.scheddule", "fifo").build().unwrap_err().to_string();
    assert!(err.contains("unknown config key"), "{err}");
    assert!(err.contains("builder"), "{err}");
    assert!(err.contains("pipeline.schedule"), "should list section keys: {err}");
}

// ---------------------------------------------------------------------------
// 2. Golden bitwise equivalence: Session vs the legacy trainer::run shim.
// ---------------------------------------------------------------------------

fn assert_runs_bitwise_equal(a: &rkfac::coordinator::RunResult, b: &rkfac::coordinator::RunResult) {
    assert_eq!(a.records.len(), b.records.len());
    for (ra, rb) in a.records.iter().zip(b.records.iter()) {
        assert_eq!(ra.train_loss, rb.train_loss, "epoch {}", ra.epoch);
        assert_eq!(ra.test_loss, rb.test_loss, "epoch {}", ra.epoch);
        assert_eq!(ra.test_acc, rb.test_acc, "epoch {}", ra.epoch);
    }
    assert_eq!(a.rank_trace.len(), b.rank_trace.len());
    for (ta, tb) in a.rank_trace.iter().zip(b.rank_trace.iter()) {
        assert_eq!(
            (ta.round, ta.epoch, ta.step, ta.block, ta.rank_a, ta.rank_g),
            (tb.round, tb.epoch, tb.step, tb.block, tb.rank_a, tb.rank_g)
        );
    }
}

/// The acceptance pin: `kfac+rsvd`, seed 0, async pipeline at
/// `max_stale_steps = 0` — Session and the legacy shim must agree bitwise.
#[test]
fn session_bitwise_matches_legacy_trainer_shim() {
    let spec = ExperimentBuilder::new()
        .toml_str(TINY_TOML)
        .unwrap()
        .set("pipeline.enabled", "true")
        .set("pipeline.workers", "2")
        .set("pipeline.max_stale_steps", "0")
        .build()
        .unwrap();
    assert_eq!(spec.cfg().solver, "kfac+rsvd");
    assert_eq!(spec.cfg().seed, 0);

    let from_session = spec.session().run().unwrap();
    let from_shim = trainer::run(spec.cfg()).unwrap();
    assert_runs_bitwise_equal(&from_session, &from_shim);

    // And without the pipeline attached (inline decompositions).
    let inline_spec = tiny_spec();
    let s = inline_spec.session().run().unwrap();
    let t = trainer::run(inline_spec.cfg()).unwrap();
    assert_runs_bitwise_equal(&s, &t);
}

/// Observer hooks must not perturb the pinned step sequence.
#[test]
fn hooks_do_not_perturb_training_bitwise() {
    let spec = tiny_spec();
    let bare = spec.session().run().unwrap();
    let mut hooked = spec.session();
    // Unreachable target: the hook observes every epoch, stops nothing.
    hooked.add_hook(Box::new(EarlyStopHook::new(2.0)));
    let hooked = hooked.run().unwrap();
    assert_runs_bitwise_equal(&bare, &hooked);
}

// ---------------------------------------------------------------------------
// 3. Sweep: one invocation == N CLI runs + summarize.
// ---------------------------------------------------------------------------

#[test]
fn sweep_reproduces_separate_runs_and_summaries() {
    let spec = tiny_spec();
    let solvers = ["kfac+rsvd", "sgd"];
    let seeds = [0u64, 1];
    let result = Sweep::new(spec.clone()).solvers(solvers).unwrap().seeds(&seeds).run().unwrap();

    assert_eq!(result.runs.len(), 4);
    assert_eq!(result.summaries.len(), 2, "one SolverSummary per solver");

    // Per-cell runs are bitwise what N separate invocations produce.
    let mut reference = Vec::new();
    for solver in solvers {
        for &seed in &seeds {
            let mut cfg = spec.cfg().clone();
            cfg.solver = solver.into();
            cfg.seed = seed;
            reference.push(trainer::run(&cfg).unwrap());
        }
    }
    for (a, b) in result.runs.iter().zip(reference.iter()) {
        assert_eq!((a.solver.as_str(), a.seed), (b.solver.as_str(), b.seed));
        assert_runs_bitwise_equal(a, b);
    }

    // And the aggregated summaries equal a by-hand summarize of the same
    // groups (the pre-API workflow), modulo wall-clock fields which are
    // re-measured per run.
    for (si, solver) in solvers.iter().enumerate() {
        let manual = metrics::summarize(&reference[si * 2..(si + 1) * 2], &spec.cfg().targets);
        let from_sweep = result.summary_for(solver).unwrap();
        assert_eq!(from_sweep.n_runs, manual.n_runs);
        assert_eq!(from_sweep.epochs_to_last.0, manual.epochs_to_last.0);
        assert_eq!(from_sweep.epochs_to_last.1, manual.epochs_to_last.1);
        // Hit counts are wall-clock independent.
        for (a, b) in from_sweep.time_to.iter().zip(manual.time_to.iter()) {
            assert_eq!(a.0, b.0, "target");
            assert_eq!(a.3, b.3, "hit count");
        }
    }
}

// ---------------------------------------------------------------------------
// [registry] wiring: out-of-tree backends named from TOML.
// ---------------------------------------------------------------------------

/// A third-party decomposition: exact EVD truncated to half the dimension.
/// Lives in the embedder's crate; the config names it via an extension.
struct HalfRank;

impl Decomposition for HalfRank {
    fn key(&self) -> &str {
        "halfrank"
    }

    fn decompose(&self, m: &Matrix, _cfg: &SketchConfig, _rng: &mut Pcg64) -> LowRankFactor {
        let e = evd::sym_evd(m).truncate((m.rows() + 1) / 2);
        LowRankFactor::new(e.u, e.lambda)
    }

    fn meta(&self, dim: usize, _cfg: &SketchConfig) -> DecompMeta {
        DecompMeta {
            key: "halfrank".into(),
            flops: 9.0 * (dim as f64).powi(3),
            randomized: false,
            projection_sides: 0,
            backend: rkfac::linalg::backend::current(),
        }
    }
}

#[test]
fn registry_section_resolves_extensions_and_solver_specs() {
    // TOML selects the extension and the solver spec it provides.
    let spec = ExperimentBuilder::new()
        .toml_str(TINY_TOML)
        .unwrap()
        .toml_str(
            "[registry]\nsolver = \"kfac+halfrank\"\nextensions = [\"halfrank-backend\"]\n",
        )
        .unwrap()
        .extension("halfrank-backend", |reg| {
            reg.register_decomposition(Arc::new(HalfRank));
        })
        .build()
        .unwrap();
    assert_eq!(spec.cfg().solver, "kfac+halfrank");
    let r = spec.session().run().unwrap();
    assert_eq!(r.records.len(), 2);
    assert!(r.records.last().unwrap().test_loss.is_finite());
    // Installed ranks reflect the half-dimension truncation: blocks are
    // (108, 32) and (32, 10) wide → ceil(d/2).
    let round0: Vec<(usize, usize)> = r
        .rank_trace
        .iter()
        .filter(|t| t.round == 0)
        .map(|t| (t.rank_a, t.rank_g))
        .collect();
    assert_eq!(round0, vec![(54, 16), (16, 5)]);

    // Without the extension selected, the same solver spec is a resolve
    // error listing the known specs.
    let err = ExperimentBuilder::new()
        .toml_str(TINY_TOML)
        .unwrap()
        .toml_str("[registry]\nsolver = \"kfac+halfrank\"\n")
        .unwrap()
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("unknown decomposition 'halfrank'"), "{err}");
    assert!(err.contains("known specs"), "{err}");
}

/// A sweep can mix built-in and extension-provided solvers; validation
/// happens against the sweep's own registry.
#[test]
fn sweep_accepts_extension_solvers() {
    let spec = ExperimentBuilder::new()
        .toml_str(TINY_TOML)
        .unwrap()
        .set("registry.extensions", "[\"halfrank-backend\"]")
        .extension("halfrank-backend", |reg| {
            reg.register_decomposition(Arc::new(HalfRank));
        })
        .build()
        .unwrap();
    let result = Sweep::new(spec)
        .solvers(["kfac+halfrank", "sgd"])
        .unwrap()
        .seeds(&[0])
        .run()
        .unwrap();
    assert_eq!(result.summaries.len(), 2);
    assert_eq!(result.summaries[0].solver, "kfac+halfrank");
}

// ---------------------------------------------------------------------------
// [schedules] end-to-end through a session run.
// ---------------------------------------------------------------------------

#[test]
fn schedules_section_drives_per_epoch_sketch() {
    // Schedule the rsvd power iterations down to 0 from epoch 1 — a
    // deliberately crude sketch late in the run. The run must still
    // complete; the point pinned here is that the section parses, resolves
    // against the registry, and reaches the engine (the crude sketch
    // changes the trained trajectory vs the §5 defaults). The workload is
    // widened to 30 steps/epoch so the T_KI = 30 cadence actually refreshes
    // inside epoch 1.
    let widen = |b: ExperimentBuilder| {
        b.set("data.n_train", "960").set("train.epochs", "2")
    };
    let with_sched = widen(ExperimentBuilder::new().toml_str(TINY_TOML).unwrap())
        .toml_str(
            "[schedules]\nrsvd_oversample_base = 10\nrsvd_oversample_steps = [1, -10]\n\
             rsvd_power_iter_base = 4\nrsvd_power_iter_steps = [1, -4]\n",
        )
        .unwrap()
        .build()
        .unwrap();
    let plain = widen(ExperimentBuilder::new().toml_str(TINY_TOML).unwrap()).build().unwrap();
    let r_sched = with_sched.session().run().unwrap();
    let r_plain = plain.session().run().unwrap();
    assert_eq!(r_sched.records.len(), r_plain.records.len());
    assert!(r_sched.records.last().unwrap().test_loss.is_finite());
    // Epoch 0 is identical (the entry resolves to the same sketch there)…
    assert_eq!(r_sched.records[0].train_loss, r_plain.records[0].train_loss);
    // …then the cruder epoch-1 sketch diverges the trajectory at the
    // first in-epoch refresh (step 30).
    assert_ne!(r_sched.records[1].train_loss, r_plain.records[1].train_loss);
}

/// Early stopping through the hook: a sweep honours the partial records.
#[test]
fn early_stop_session_keeps_partial_records() {
    let spec = tiny_spec();
    let mut session = spec.session();
    session.add_hook(Box::new(EarlyStopHook::new(0.0))); // hit at epoch 0
    let r = session.run().unwrap();
    assert_eq!(r.records.len(), 1);
    assert!(r.time_to_acc(0.0).is_some());
}
