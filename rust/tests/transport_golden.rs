//! Transport golden suite: the distributed factor service must be
//! *location-transparent*. At `max_stale_steps = 0`, a trainer whose
//! decompositions run on a remote factor server (TCP loopback or a
//! shared-directory mailbox) must reproduce the in-process pipelined run
//! bit-for-bit — every decomposition is a pure function of
//! `(matrix, cfg, derived rng)`, and f64 le-bytes round-trip losslessly.
//! Killing the server mid-run (or pointing at a dead endpoint) must
//! degrade to inline decomposition without changing the trajectory.
//!
//! Plus the preemptible-sweep contract: a board worker killed after one
//! cell leaves a grid that a re-run finishes by executing *only* the
//! remaining cells, with the aggregated results matching the
//! uninterrupted in-process sweep.

use rkfac::coordinator::config::{DataChoice, EngineChoice, ModelChoice, TrainConfig};
use rkfac::coordinator::experiment::{ExperimentBuilder, ExperimentSpec};
use rkfac::coordinator::metrics::RunResult;
use rkfac::coordinator::session::Session;
use rkfac::coordinator::sweep::Sweep;
use rkfac::pipeline::transport::FactorServer;
use rkfac::pipeline::{PipelineConfig, TransportKind};
use rkfac::rnla::DecompositionRegistry;

fn tiny_cfg(solver: &str) -> TrainConfig {
    TrainConfig {
        solver: solver.into(),
        epochs: 2,
        batch: 32,
        seed: 7,
        model: ModelChoice::Mlp { widths: vec![108, 32, 10] },
        data: DataChoice::Synthetic { n_train: 160, n_test: 64, height: 6, width: 6, channels: 1 },
        engine: EngineChoice::Native,
        targets: vec![0.15],
        augment: false,
        out_dir: "/tmp/rkfac_transport_golden".into(),
        sched_width: 0,
        ..Default::default()
    }
}

/// Pipelined config at the bitwise point (stale = 0) with the given
/// transport.
fn pipe(transport: TransportKind, endpoint: &str) -> PipelineConfig {
    PipelineConfig {
        enabled: true,
        workers: 2,
        max_stale_steps: 0,
        transport,
        endpoint: endpoint.into(),
        ..Default::default()
    }
}

fn run_with(pipeline: PipelineConfig, solver: &str) -> RunResult {
    let mut cfg = tiny_cfg(solver);
    cfg.pipeline = pipeline;
    Session::new(cfg).run().expect("run failed")
}

/// Compare the deterministic per-epoch fields bit-for-bit (wall-clock
/// fields are excluded — they are measurements, not trajectory).
fn assert_bitwise(got: &RunResult, want: &RunResult, what: &str) {
    assert_eq!(got.records.len(), want.records.len(), "{what}: record count");
    for (g, w) in got.records.iter().zip(&want.records) {
        assert_eq!(g.epoch, w.epoch, "{what}: epoch order");
        assert_eq!(
            g.train_loss.to_bits(),
            w.train_loss.to_bits(),
            "{what}: train_loss diverged at epoch {} ({} vs {})",
            g.epoch,
            g.train_loss,
            w.train_loss
        );
        assert_eq!(
            g.test_loss.to_bits(),
            w.test_loss.to_bits(),
            "{what}: test_loss diverged at epoch {}",
            g.epoch
        );
        assert_eq!(
            g.test_acc.to_bits(),
            w.test_acc.to_bits(),
            "{what}: test_acc diverged at epoch {}",
            g.epoch
        );
    }
}

#[test]
fn tcp_loopback_reproduces_local_bitwise() {
    let local = run_with(pipe(TransportKind::Local, ""), "rs-kfac");
    let server = FactorServer::spawn_tcp("127.0.0.1:0", 2, DecompositionRegistry::with_defaults())
        .expect("spawn tcp server");
    let addr = server.addr().expect("bound addr").to_string();
    let tcp = run_with(pipe(TransportKind::Tcp, &addr), "rs-kfac");
    assert_bitwise(&tcp, &local, "tcp loopback vs local");
    // Anchor: the local pipelined run itself matches the inline path at
    // stale = 0 (the PR-3 contract the transports inherit).
    let inline = run_with(PipelineConfig::default(), "rs-kfac");
    assert_bitwise(&local, &inline, "local pipeline vs inline");
}

#[test]
fn dir_mailbox_reproduces_local_bitwise() {
    let root = std::env::temp_dir().join(format!("rkfac_golden_mail_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).unwrap();
    let local = run_with(pipe(TransportKind::Local, ""), "rs-kfac");
    let server = FactorServer::spawn_dir(&root, 2, DecompositionRegistry::with_defaults())
        .expect("spawn dir server");
    let dir = run_with(pipe(TransportKind::Dir, root.to_str().unwrap()), "rs-kfac");
    assert_bitwise(&dir, &local, "dir mailbox vs local");
    drop(server);
    std::fs::remove_dir_all(&root).ok();
}

/// Killing the factor server mid-run must degrade the trainer to inline
/// decomposition — slower, but bitwise-identical at stale = 0 and never
/// fatal, wherever in the run the kill lands.
#[test]
fn server_killed_mid_run_degrades_inline_without_divergence() {
    let local = run_with(pipe(TransportKind::Local, ""), "rs-kfac");
    let mut server =
        FactorServer::spawn_tcp("127.0.0.1:0", 2, DecompositionRegistry::with_defaults())
            .expect("spawn tcp server");
    let addr = server.addr().expect("bound addr").to_string();
    let mut pipeline = pipe(TransportKind::Tcp, &addr);
    // Tight timeouts so the post-kill fallback costs milliseconds, not the
    // 5 s default.
    pipeline.connect_timeout_ms = 200;
    pipeline.io_timeout_ms = 200;
    pipeline.max_retries = 1;
    let runner = std::thread::spawn(move || run_with(pipeline, "rs-kfac"));
    std::thread::sleep(std::time::Duration::from_millis(120));
    server.shutdown();
    let degraded = runner.join().expect("trainer must survive the server kill");
    assert_bitwise(&degraded, &local, "server killed mid-run vs local");
}

/// A dead endpoint (nothing ever listening) must behave like a permanently
/// degraded service: every submit falls back inline, the run completes,
/// and the trajectory is unchanged.
#[test]
fn dead_endpoint_falls_back_inline_bitwise() {
    let local = run_with(pipe(TransportKind::Local, ""), "rs-kfac");
    let mut pipeline = pipe(TransportKind::Tcp, "127.0.0.1:9");
    pipeline.connect_timeout_ms = 50;
    pipeline.io_timeout_ms = 50;
    pipeline.max_retries = 1;
    let degraded = run_with(pipeline, "rs-kfac");
    assert_bitwise(&degraded, &local, "dead endpoint vs local");
}

fn sweep_spec() -> ExperimentSpec {
    ExperimentBuilder::new()
        .toml_str(
            "[model]\nkind = \"mlp\"\nwidths = [108, 32, 10]\n\
             [data]\nkind = \"synthetic\"\nn_train = 160\nn_test = 64\nheight = 6\nwidth = 6\n\
             [train]\nepochs = 1\nbatch = 32\ntargets = [0.15]\n",
        )
        .unwrap()
        .build()
        .unwrap()
}

/// Kill-and-resume sweep smoke: a 2×2 grid worker "dies" after one cell;
/// the re-run executes exactly the three remaining cells (the done cell's
/// manifest is the authority), and the aggregated result matches the
/// uninterrupted in-process grid.
#[test]
fn remote_sweep_resume_executes_only_incomplete_cells() {
    let board = std::env::temp_dir().join(format!("rkfac_golden_board_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&board);
    let board_str = board.to_str().unwrap().to_string();

    let grid =
        || Sweep::new(sweep_spec()).solvers(["sgd", "rs-kfac"]).unwrap().runs_per_solver(2);
    let uninterrupted = grid().run().unwrap();

    // "Worker killed after one cell": run exactly one cell, then stop.
    let sweep = grid();
    assert_eq!(sweep.len(), 4, "2x2 grid");
    let first_pass = sweep.work_board(&board_str, 1).unwrap();
    assert_eq!(first_pass, 1, "the killed worker completed one cell");
    let count = |sub: &str| std::fs::read_dir(board.join(sub)).unwrap().count();
    assert_eq!((count("done"), count("pending")), (1, 3));

    // The re-run claims and executes only the three incomplete cells.
    let second_pass = grid().work_board(&board_str, 0).unwrap();
    assert_eq!(second_pass, 3, "re-run executes only the remaining cells");
    assert_eq!((count("done"), count("pending")), (4, 0));

    // Aggregation over the manifests matches the uninterrupted grid on
    // every deterministic field and summary.
    let remote = grid().run_remote(&board_str).unwrap();
    assert!(remote.is_complete());
    assert_eq!(remote.runs.len(), uninterrupted.runs.len());
    for (g, w) in remote.runs.iter().zip(&uninterrupted.runs) {
        assert_eq!((g.solver.as_str(), g.seed), (w.solver.as_str(), w.seed));
        assert_bitwise(g, w, "remote sweep cell vs in-process");
    }
    assert_eq!(remote.summaries.len(), uninterrupted.summaries.len());
    for (g, w) in remote.summaries.iter().zip(&uninterrupted.summaries) {
        assert_eq!(g.solver, w.solver);
        assert_eq!(g.n_runs, w.n_runs);
    }
    std::fs::remove_dir_all(&board).ok();
}
