"""Tiled Pallas matmul — the MXU building block every other kernel reuses.

`matmul(a, b)` computes `A @ B` with a (bm, bn, bk) grid: the k axis is the
innermost (reduction) grid dimension, accumulating into the output tile that
stays resident in VMEM across the k sweep (revisiting semantics). This is
the BlockSpec expression of the HBM->VMEM->MXU pipeline the paper's GPU
implementation got from cuBLAS.

`matmul_axpy(a, b, c0, beta)` fuses `A @ B + beta * C0` — the tail of the
equation-(13) low-rank inverse apply, saving one HBM round-trip of the
output panel.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import BLOCK, INTERPRET, cdiv, pad2, pick_block


def _matmul_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype)


def _matmul_axpy_kernel(a_ref, b_ref, c_ref, o_ref, *, k_steps):
    # beta is folded into C before the call (it may be a traced scalar, e.g.
    # the 1/lambda of the damping schedule, which a kernel closure cannot
    # capture); the kernel adds the pre-scaled tile on the last k step.
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _tail():
        o_ref[...] += c_ref[...]


def matmul(a, b, *, bm: int = BLOCK, bn: int = BLOCK, bk: int = BLOCK):
    """`A @ B` via the tiled Pallas kernel (shapes padded to tile multiples)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"matmul: inner dims {k} != {k2}"
    bm, bn, bk = pick_block(m, bm), pick_block(n, bn), pick_block(k, bk)
    ap, bp = pad2(a, bm, bk), pad2(b, bk, bn)
    mp, kp = ap.shape
    _, np_ = bp.shape
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, p: (i, p)),
            pl.BlockSpec((bk, bn), lambda i, j, p: (p, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, p: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        interpret=INTERPRET,
    )(ap, bp)
    return out[:m, :n]


def matmul_axpy(a, b, c0, beta, *, bm: int = BLOCK, bn: int = BLOCK, bk: int = BLOCK):
    """Fused `A @ B + beta * C0` (C0 shaped like the product)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"matmul_axpy: inner dims {k} != {k2}"
    assert c0.shape == (m, n), f"matmul_axpy: c0 shape {c0.shape} != {(m, n)}"
    bm, bn, bk = pick_block(m, bm), pick_block(n, bn), pick_block(k, bk)
    ap, bp, cp = pad2(a, bm, bk), pad2(b, bk, bn), pad2(beta * c0, bm, bn)
    mp, kp = ap.shape
    _, np_ = bp.shape
    grid = (mp // bm, np_ // bn, kp // bk)
    kernel = functools.partial(_matmul_axpy_kernel, k_steps=grid[2])
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, p: (i, p)),
            pl.BlockSpec((bk, bn), lambda i, j, p: (p, j)),
            pl.BlockSpec((bm, bn), lambda i, j, p: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, p: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        interpret=INTERPRET,
    )(ap, bp, cp)
    return out[:m, :n]
