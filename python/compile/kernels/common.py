"""Shared tiling helpers for the Pallas kernels.

TPU-shaped tiling notes (DESIGN.md §Hardware-Adaptation): the MXU wants
128x128 panels and VMEM is a ~16 MB scratchpad, so every kernel here blocks
its operands into (128, 128) f32 tiles by default and expresses the
HBM<->VMEM schedule with BlockSpec index maps. On this testbed the kernels
execute under `interpret=True` (the CPU PJRT client cannot run Mosaic
custom-calls), so the tiling is validated structurally, not for wall-clock.

All wrappers zero-pad operands up to a multiple of the block size and slice
the result back, so arbitrary problem shapes (e.g. the 10-class logit layer)
are supported without masking logic inside the kernel bodies.
"""

import jax.numpy as jnp

# Default MXU-aligned tile edge.
BLOCK = 128

# interpret=True is mandatory on CPU; real-TPU lowering would emit a Mosaic
# custom-call the CPU plugin cannot execute (see /opt/xla-example/README.md).
INTERPRET = True


def cdiv(a: int, b: int) -> int:
    """Ceiling division."""
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    """Round `a` up to a multiple of `b`."""
    return cdiv(a, b) * b


def pad2(x, br: int, bc: int):
    """Zero-pad a 2-D array so both dims are multiples of (br, bc)."""
    r, c = x.shape
    pr, pc = round_up(r, br) - r, round_up(c, bc) - c
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


def pick_block(dim: int, preferred: int = BLOCK) -> int:
    """Pick a tile edge: the preferred MXU tile, shrunk for tiny dims."""
    return preferred if dim >= preferred else max(8, round_up(dim, 8))
