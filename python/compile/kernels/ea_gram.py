"""Fused EA gram update kernel — Algorithm 1, lines 4 & 8.

Computes `rho * OLD + (1 - rho)/denom * M @ M.T` for a d x n factor matrix M
(n ∝ batch size << d). One grid step produces one (bm, bn) tile of the d x d
output from two row panels of M: the (i) panel and the (j) panel both stream
HBM->VMEM while the OLD tile is read once and blended in-register. On TPU
this is a single pass over M per output block row — the batch dimension n is
small enough that a whole (bm, n) panel fits VMEM (bm*n*4 bytes ≈ 256 KB at
bm=128, n=512).

rho/denom are compile-time constants: the EA decay is a fixed hyperparameter
(paper: rho = 0.95) and denom is the batch size, both baked at AOT time.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import BLOCK, INTERPRET, pad2, pick_block


def _ea_gram_kernel(old_ref, mi_ref, mj_ref, o_ref, *, rho, coeff):
    gram = jnp.dot(mi_ref[...], mj_ref[...].T, preferred_element_type=o_ref.dtype)
    o_ref[...] = rho * old_ref[...] + coeff * gram


def ea_gram(old, m, *, rho: float, denom: float, bm: int = BLOCK, bn: int = BLOCK):
    """`rho*old + (1-rho)/denom * m @ m.T`; old: (d, d), m: (d, n)."""
    d, n = m.shape
    assert old.shape == (d, d), f"ea_gram: old shape {old.shape} != {(d, d)}"
    # A single tile edge for both output axes keeps the two M row-panel
    # specs addressing the same padded buffer.
    bm = bn = pick_block(d, min(bm, bn))
    # Pad the factor's batch dim to the sublane multiple; zero columns do not
    # change M @ M.T. Pad old's both dims to the tile grid.
    mp = pad2(m, bm, 8)
    oldp = pad2(old, bm, bn)
    dp = oldp.shape[0]
    npad = mp.shape[1]
    grid = (dp // bm, dp // bn)
    kernel = functools.partial(_ea_gram_kernel, rho=rho, coeff=(1.0 - rho) / denom)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),  # old tile
            pl.BlockSpec((bm, npad), lambda i, j: (i, 0)),  # M row-panel i
            pl.BlockSpec((bn, npad), lambda i, j: (j, 0)),  # M row-panel j
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((dp, dp), old.dtype),
        interpret=INTERPRET,
    )(oldp, mp, mp)
    return out[:d, :d]
