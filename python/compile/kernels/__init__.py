"""L1 Pallas kernels (build-time only; lowered into the model HLO).

Modules: ea_gram (EA gram update), matmul (tiled MXU matmul + fused axpy),
lowrank_apply (eq. 13), sketch (randomized range finder), ref (jnp oracles).
"""

from . import common, ea_gram, lowrank_apply, matmul, ref, sketch  # noqa: F401
