"""Equation-(13) low-rank damped inverse apply, built from the Pallas tiles.

`(U diag(d) U^T + lam*I)^{-1} V
    = U [ (d+lam)^{-1} - lam^{-1} ] U^T V + lam^{-1} V`

Stage 1: W = U^T V              (thin matmul, r x c — r is the paper's
                                 target rank ~230, so W lives in VMEM)
Stage 2: W <- coeff[:, None]*W  (row scaling, fused into stage 3's A operand)
Stage 3: out = U @ W + lam^{-1} V  (fused matmul_axpy — one pass over V)

The damping lam is a traced scalar input (it follows the paper's λ(epoch)
schedule), so the same compiled artifact serves the whole run.
"""

import jax.numpy as jnp

from .matmul import matmul, matmul_axpy


def lowrank_apply(u, d, lam, v):
    """Apply `(U diag(d) U^T + lam I)^{-1}` to V. u: (dim, r), v: (dim, c)."""
    dim, r = u.shape
    assert v.shape[0] == dim, f"lowrank_apply: dim mismatch {v.shape} vs {u.shape}"
    assert d.shape == (r,), f"lowrank_apply: d shape {d.shape} != ({r},)"
    inv_l = 1.0 / lam
    w = matmul(u.T, v)  # r x c
    coeff = 1.0 / (d + lam) - inv_l  # r
    w = coeff[:, None] * w
    return matmul_axpy(u, w, v, inv_l)


def lowrank_apply_right(u, d, lam, v):
    """Apply from the right: `V (U diag(d) U^T + lam I)^{-1}`; v: (c, dim)."""
    return lowrank_apply(u, d, lam, v.T).T


def lowrank_precondition(ug, dg, ua, da, lam, grad):
    """Full K-FAC preconditioning of one layer's gradient (Alg. 4 lines 7-8):

    `(Gamma + lam I)^{-1} Grad (A + lam I)^{-1}`

    with both Kronecker factors in truncated eigen form. grad: (d_out, d_in).
    """
    left = lowrank_apply(ug, dg, lam, grad)
    return lowrank_apply_right(ua, da, lam, left)
