"""Randomized range-finder sketch kernel (Alg. 2/3, lines 3-5).

The flop-heavy part of RSVD/SREVD is the sketch `Y = X @ Omega` and its
power-iteration refinements `Y <- X (X^T Y)`; both are expressed with the
tiled Pallas matmul so the whole sketch pipeline lowers into MXU-shaped HLO.
The (r+l)-column QR between power iterations is O(d (r+l)^2) and is left to
XLA's native QR (it is not an MXU-friendly op), mirroring how the Rust L3
implementation splits work between `gemm` and `qr`.
"""

import jax.numpy as jnp

from .matmul import matmul


def sketch(x, omega):
    """Single-pass sketch `Y = X @ Omega`."""
    return matmul(x, omega)


def range_sketch(x, omega, n_pwr_it: int):
    """Power-iterated orthonormal range basis Q of X (Halko Alg. 4.4).

    Returns Q with orthonormal columns spanning approx. range(X).
    """
    y = matmul(x, omega)
    q, _ = jnp.linalg.qr(y)
    for _ in range(n_pwr_it):
        z = matmul(x.T, q)
        qz, _ = jnp.linalg.qr(z)
        y = matmul(x, qz)
        q, _ = jnp.linalg.qr(y)
    return q


def srevd_core(x, omega, n_pwr_it: int):
    """SREVD small-core path (Alg. 3 lines 4-7): returns (Q, C = Q^T X Q).

    The eigendecomposition of the tiny (r+l)x(r+l) C happens on the consumer
    side (Rust L3 or jnp.linalg.eigh in tests) — it is O((r+l)^3), negligible.
    """
    q = range_sketch(x, omega, n_pwr_it)
    xq = matmul(x, q)
    c = matmul(q.T, xq)
    return q, 0.5 * (c + c.T)
