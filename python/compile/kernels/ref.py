"""Pure-jnp oracles for every Pallas kernel in this package.

Each `*_ref` function is the mathematical definition of the corresponding
kernel; pytest (python/tests/test_kernels.py) sweeps shapes/dtypes with
hypothesis and asserts `assert_allclose(kernel(...), ref(...))`. The refs
are also what the Rust-native implementations are cross-checked against
(rust/tests/ integration suite compares against artifact outputs).
"""

import jax.numpy as jnp


def ea_gram_ref(old, m, rho, denom):
    """EA gram update: rho*old + (1-rho)/denom * M @ M.T  (Alg. 1 lines 4/8)."""
    return rho * old + (1.0 - rho) / denom * (m @ m.T)


def matmul_ref(a, b):
    """Plain matmul C = A @ B."""
    return a @ b


def lowrank_apply_ref(u, d, lam, v):
    """Equation (13): (U diag(d) U^T + lam I)^{-1} V via the low-rank identity.

    = U [ (d+lam)^{-1} - lam^{-1} ] U^T V + lam^{-1} V
    """
    coeff = 1.0 / (d + lam) - 1.0 / lam
    w = u.T @ v
    return u @ (coeff[:, None] * w) + v / lam


def sketch_ref(x, omega):
    """Range-finder sketch Y = X @ Omega (Alg. 2/3 line 4, single pass)."""
    return x @ omega


def mlp_forward_ref(ws, x):
    """ReLU MLP forward (no biases): returns logits (classes, batch).

    ws: list of (d_out, d_in) weights; x: (d_in0, batch).
    """
    h = x
    for i, w in enumerate(ws):
        z = w @ h
        h = jnp.maximum(z, 0.0) if i + 1 < len(ws) else z
    return h


def softmax_xent_ref(logits, y_onehot):
    """Mean softmax cross-entropy. logits, y_onehot: (classes, batch)."""
    zmax = logits.max(axis=0, keepdims=True)
    logz = zmax + jnp.log(jnp.exp(logits - zmax).sum(axis=0, keepdims=True))
    logp = logits - logz
    return -(y_onehot * logp).sum(axis=0).mean()
