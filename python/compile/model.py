"""L2 — the JAX model: ReLU MLP fwd/bwd with K-factor capture.

This is the compute graph the Rust coordinator drives through PJRT. One
`model_step` call fuses, in a single lowered HLO module:

  1. forward pass (Pallas tiled matmuls),
  2. softmax cross-entropy loss,
  3. manual backward pass producing per-layer weight gradients,
  4. the *empirical-NG* K-factor grams (paper §5: backward factors built
     from the label gradients, not sampled ones),
  5. the EA blend of both gram families (Pallas `ea_gram` kernel — Alg. 1
     lines 4/8) so the coordinator receives ready-to-decompose EA factors.

Conventions (column-major batch like the paper's math):
  - x: (d0, B) input batch; y: (C, B) one-hot labels.
  - Layer l weight W_l: (d_{l+1}, d_l); no biases (see DESIGN.md).
  - A^(l) = h_l (d_l, B): the layer input activations -> forward factor
    Abar = rho*Abar + (1-rho)/B * A A^T.
  - G^(l) = dL/dz_l * B (d_{l+1}, B): pre-activation gradients, scaled by B
    so G G^T / B matches the per-sample outer-product average.
  - grad W_l = (dL/dz_l) h_l^T  (mean loss => already 1/B-scaled).

The backward pass is hand-written (not jax.grad) so the K-factor
intermediates are first-class outputs and the lowered HLO stays free of
transpose-of-transpose noise.
"""

import jax
import jax.numpy as jnp

from .kernels.ea_gram import ea_gram
from .kernels.matmul import matmul


def init_params(widths, key):
    """He-initialized weights for an MLP with the given layer widths."""
    ws = []
    for i in range(len(widths) - 1):
        key, sub = jax.random.split(key)
        scale = jnp.sqrt(2.0 / widths[i])
        ws.append(scale * jax.random.normal(sub, (widths[i + 1], widths[i]), jnp.float32))
    return ws


def forward(ws, x):
    """Forward pass; returns (logits, activations) with activations[l] = h_l."""
    acts = [x]
    h = x
    n = len(ws)
    for i, w in enumerate(ws):
        z = matmul(w, h)
        h = jnp.maximum(z, 0.0) if i + 1 < n else z
        if i + 1 < n:
            acts.append(h)
    return h, acts


def softmax_xent(logits, y_onehot):
    """Mean softmax cross-entropy and the batch softmax probabilities."""
    zmax = jax.lax.stop_gradient(logits.max(axis=0, keepdims=True))
    ez = jnp.exp(logits - zmax)
    p = ez / ez.sum(axis=0, keepdims=True)
    logp = logits - zmax - jnp.log(ez.sum(axis=0, keepdims=True))
    loss = -(y_onehot * logp).sum(axis=0).mean()
    return loss, p


def backward(ws, acts, p, y_onehot):
    """Manual backprop. Returns (grads, g_factors).

    grads[l]: dL/dW_l, shape (d_{l+1}, d_l).
    g_factors[l]: G^(l) = B * dL/dz_l, shape (d_{l+1}, B).
    """
    batch = y_onehot.shape[1]
    n = len(ws)
    grads = [None] * n
    g_factors = [None] * n
    # dL/dz for the logits layer (mean reduction -> 1/B).
    dz = (p - y_onehot) / batch
    for l in range(n - 1, -1, -1):
        grads[l] = matmul(dz, acts[l].T)
        g_factors[l] = dz * batch
        if l > 0:
            dh = matmul(ws[l].T, dz)
            dz = dh * (acts[l] > 0.0)
    return grads, g_factors


def model_step(ws, old_a, old_g, x, y_onehot, *, rho: float):
    """One fused training-step compute: loss, grads, EA K-factor updates.

    Returns (loss, grads, new_a, new_g):
      new_a[l] = rho*old_a[l] + (1-rho)/B * h_l h_l^T
      new_g[l] = rho*old_g[l] + (1-rho)/B * G_l G_l^T
    """
    batch = x.shape[1]
    logits, acts = forward(ws, x)
    loss, p = softmax_xent(logits, y_onehot)
    grads, g_factors = backward(ws, acts, p, y_onehot)
    new_a = [ea_gram(old_a[l], acts[l], rho=rho, denom=float(batch)) for l in range(len(ws))]
    new_g = [
        ea_gram(old_g[l], g_factors[l], rho=rho, denom=float(batch)) for l in range(len(ws))
    ]
    return loss, grads, new_a, new_g


def model_eval(ws, x, y_onehot):
    """Evaluation pass: (mean loss, #correct predictions in the batch)."""
    logits, _ = forward(ws, x)
    loss, _ = softmax_xent(logits, y_onehot)
    pred = jnp.argmax(logits, axis=0)
    truth = jnp.argmax(y_onehot, axis=0)
    correct = (pred == truth).sum().astype(jnp.float32)
    return loss, correct


def sgd_step(ws, x, y_onehot, *, lr: float, weight_decay: float):
    """Fused SGD step (baseline solver): returns (loss, new weights)."""
    logits, acts = forward(ws, x)
    loss, p = softmax_xent(logits, y_onehot)
    grads, _ = backward(ws, acts, p, y_onehot)
    new_ws = [w - lr * (g + weight_decay * w) for w, g in zip(ws, grads)]
    return loss, new_ws


# ---------------------------------------------------------------------------
# Flattened entry points for AOT lowering (PJRT takes a flat argument list).
# ---------------------------------------------------------------------------


def make_step_fn(widths, batch: int, rho: float):
    """Flat-signature `model_step` for the given architecture.

    Signature: (W_0..W_{L-1}, A_0..A_{L-1}, G_0..G_{L-1}, x, y) ->
               (loss, dW_0.., newA_0.., newG_0..)
    """
    n = len(widths) - 1

    def step(*args):
        ws = list(args[:n])
        old_a = list(args[n : 2 * n])
        old_g = list(args[2 * n : 3 * n])
        x, y = args[3 * n], args[3 * n + 1]
        loss, grads, new_a, new_g = model_step(ws, old_a, old_g, x, y, rho=rho)
        return tuple([loss] + grads + new_a + new_g)

    f32 = jnp.float32
    ins = (
        [jax.ShapeDtypeStruct((widths[i + 1], widths[i]), f32) for i in range(n)]
        + [jax.ShapeDtypeStruct((widths[i], widths[i]), f32) for i in range(n)]
        + [jax.ShapeDtypeStruct((widths[i + 1], widths[i + 1]), f32) for i in range(n)]
        + [
            jax.ShapeDtypeStruct((widths[0], batch), f32),
            jax.ShapeDtypeStruct((widths[-1], batch), f32),
        ]
    )
    return step, ins


def make_eval_fn(widths, batch: int):
    """Flat-signature `model_eval`: (W_0.., x, y) -> (loss, correct)."""
    n = len(widths) - 1

    def ev(*args):
        ws = list(args[:n])
        x, y = args[n], args[n + 1]
        return model_eval(ws, x, y)

    f32 = jnp.float32
    ins = [jax.ShapeDtypeStruct((widths[i + 1], widths[i]), f32) for i in range(n)] + [
        jax.ShapeDtypeStruct((widths[0], batch), f32),
        jax.ShapeDtypeStruct((widths[-1], batch), f32),
    ]
    return ev, ins


def make_sgd_fn(widths, batch: int, lr: float, weight_decay: float):
    """Flat-signature fused SGD step: (W_0.., x, y) -> (loss, W_0'..)."""
    n = len(widths) - 1

    def step(*args):
        ws = list(args[:n])
        x, y = args[n], args[n + 1]
        loss, new_ws = sgd_step(ws, x, y, lr=lr, weight_decay=weight_decay)
        return tuple([loss] + new_ws)

    f32 = jnp.float32
    ins = [jax.ShapeDtypeStruct((widths[i + 1], widths[i]), f32) for i in range(n)] + [
        jax.ShapeDtypeStruct((widths[0], batch), f32),
        jax.ShapeDtypeStruct((widths[-1], batch), f32),
    ]
    return step, ins
