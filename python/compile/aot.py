"""AOT compile path: lower L2/L1 entry points to HLO text artifacts.

Run once via `make artifacts` (no-op when up to date):

    cd python && python -m compile.aot --out ../artifacts

Interchange format is **HLO text**, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Each artifact gets an entry in `manifest.json` with the full input/output
shape/dtype signature; the Rust runtime (`rust/src/runtime/registry.rs`)
parses that to marshal buffers without re-deriving shapes.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.ea_gram import ea_gram
from .kernels.lowrank_apply import lowrank_apply
from .kernels.sketch import sketch

# ---------------------------------------------------------------------------
# Model configurations exported by default. `tiny` exists for the fast Rust
# integration tests; `quick` is the Table-1/Fig-2 training workhorse; `wide`
# stresses the wide-layer regime where Randomized K-FACs shine.
# ---------------------------------------------------------------------------
MODEL_CONFIGS = {
    "tiny": {"widths": [64, 32, 10], "batch": 16, "rho": 0.95},
    "quick": {"widths": [768, 256, 256, 10], "batch": 128, "rho": 0.95},
    "wide": {"widths": [768, 1024, 10], "batch": 128, "rho": 0.95},
}

# Standalone kernel artifact shapes (runtime benches + integration tests).
EA_GRAM_SHAPES = [(256, 128)]  # (d, n)
LOWRANK_SHAPES = [(256, 64, 256)]  # (d, r, c)
SKETCH_SHAPES = [(256, 74)]  # (d, s)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_of(s) -> dict:
    return {"shape": list(s.shape), "dtype": s.dtype.name}


def lower_artifact(name: str, fn, in_specs, out_dir: str, meta=None) -> dict:
    """Lower `fn` at `in_specs`, write `<name>.hlo.txt`, return manifest row."""
    lowered = jax.jit(fn).lower(*in_specs)
    text = to_hlo_text(lowered)
    path = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(text)
    # Output signature from the lowered computation's abstract values.
    out_avals = jax.eval_shape(fn, *in_specs)
    if not isinstance(out_avals, tuple):
        out_avals = (out_avals,)
    row = {
        "name": name,
        "file": path,
        "inputs": [spec_of(s) for s in in_specs],
        "outputs": [spec_of(s) for s in out_avals],
    }
    if meta:
        row["meta"] = meta
    print(f"  wrote {path} ({len(text)} chars, {len(in_specs)} in / {len(out_avals)} out)")
    return row


def build_all(out_dir: str, configs=None) -> None:
    os.makedirs(out_dir, exist_ok=True)
    rows = []
    cfg_names = configs or list(MODEL_CONFIGS)

    for cname in cfg_names:
        cfg = MODEL_CONFIGS[cname]
        widths, batch, rho = cfg["widths"], cfg["batch"], cfg["rho"]
        meta = {"kind": "model", "widths": widths, "batch": batch, "rho": rho}
        step, step_ins = M.make_step_fn(widths, batch, rho)
        rows.append(lower_artifact(f"mlp_step_{cname}", step, step_ins, out_dir, meta))
        ev, ev_ins = M.make_eval_fn(widths, batch)
        rows.append(lower_artifact(f"mlp_eval_{cname}", ev, ev_ins, out_dir, meta))
        sgd, sgd_ins = M.make_sgd_fn(widths, batch, lr=0.1, weight_decay=7e-4)
        meta_sgd = dict(meta, lr=0.1, weight_decay=7e-4)
        rows.append(lower_artifact(f"mlp_sgd_{cname}", sgd, sgd_ins, out_dir, meta_sgd))

    f32 = jnp.float32
    for d, n in EA_GRAM_SHAPES:
        fn = lambda old, m: (ea_gram(old, m, rho=0.95, denom=float(n)),)
        ins = [jax.ShapeDtypeStruct((d, d), f32), jax.ShapeDtypeStruct((d, n), f32)]
        rows.append(
            lower_artifact(
                f"ea_gram_{d}x{n}", fn, ins, out_dir, {"kind": "ea_gram", "rho": 0.95, "denom": n}
            )
        )

    for d, r, c in LOWRANK_SHAPES:
        fn = lambda u, dv, lam, v: (lowrank_apply(u, dv, lam, v),)
        ins = [
            jax.ShapeDtypeStruct((d, r), f32),
            jax.ShapeDtypeStruct((r,), f32),
            jax.ShapeDtypeStruct((), f32),
            jax.ShapeDtypeStruct((d, c), f32),
        ]
        rows.append(lower_artifact(f"lowrank_apply_{d}_{r}_{c}", fn, ins, out_dir, {"kind": "lowrank"}))

    for d, s in SKETCH_SHAPES:
        fn = lambda x, om: (sketch(x, om),)
        ins = [jax.ShapeDtypeStruct((d, d), f32), jax.ShapeDtypeStruct((d, s), f32)]
        rows.append(lower_artifact(f"sketch_{d}_{s}", fn, ins, out_dir, {"kind": "sketch"}))

    manifest = {"version": 1, "artifacts": rows}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest.json: {len(rows)} artifacts")


def main() -> None:
    ap = argparse.ArgumentParser(description="AOT-lower model + kernels to HLO text")
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--configs",
        default=None,
        help="comma-separated model config names (default: all)",
    )
    args = ap.parse_args()
    configs = args.configs.split(",") if args.configs else None
    build_all(args.out, configs)


if __name__ == "__main__":
    main()
