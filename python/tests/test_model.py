"""L2 model correctness: manual fwd/bwd vs jax.grad, K-factor semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

WIDTHS = [12, 8, 10]
BATCH = 6


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    ws = M.init_params(WIDTHS, key)
    kx, ky = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(kx, (WIDTHS[0], BATCH), jnp.float32)
    labels = jax.random.randint(ky, (BATCH,), 0, WIDTHS[-1])
    y = jax.nn.one_hot(labels, WIDTHS[-1], axis=0, dtype=jnp.float32)
    return ws, x, y


def test_forward_matches_ref(setup):
    ws, x, _ = setup
    logits, acts = M.forward(ws, x)
    np.testing.assert_allclose(logits, ref.mlp_forward_ref(ws, x), rtol=1e-4, atol=1e-5)
    assert len(acts) == len(ws)
    np.testing.assert_array_equal(np.asarray(acts[0]), np.asarray(x))


def test_loss_matches_ref(setup):
    ws, x, y = setup
    logits, _ = M.forward(ws, x)
    loss, p = M.softmax_xent(logits, y)
    np.testing.assert_allclose(loss, ref.softmax_xent_ref(logits, y), rtol=1e-5)
    np.testing.assert_allclose(p.sum(axis=0), np.ones(BATCH), rtol=1e-5)


def test_manual_grads_match_jax_grad(setup):
    ws, x, y = setup

    def loss_fn(ws_):
        logits = ref.mlp_forward_ref(ws_, x)
        return ref.softmax_xent_ref(logits, y)

    auto = jax.grad(loss_fn)(ws)
    logits, acts = M.forward(ws, x)
    _, p = M.softmax_xent(logits, y)
    manual, _ = M.backward(ws, acts, p, y)
    for l, (a, m) in enumerate(zip(auto, manual)):
        np.testing.assert_allclose(m, a, rtol=5e-4, atol=1e-5, err_msg=f"layer {l}")


def test_g_factor_consistent_with_grad(setup):
    # grad W_l must equal (G_l / B) @ acts_l^T  — the K-FAC identity.
    ws, x, y = setup
    logits, acts = M.forward(ws, x)
    _, p = M.softmax_xent(logits, y)
    grads, gf = M.backward(ws, acts, p, y)
    for l in range(len(ws)):
        recon = (gf[l] / BATCH) @ acts[l].T
        np.testing.assert_allclose(recon, grads[l], rtol=5e-4, atol=1e-6, err_msg=f"layer {l}")


def test_model_step_ea_semantics(setup):
    ws, x, y = setup
    n = len(ws)
    old_a = [jnp.eye(WIDTHS[i], dtype=jnp.float32) for i in range(n)]
    old_g = [jnp.eye(WIDTHS[i + 1], dtype=jnp.float32) for i in range(n)]
    rho = 0.9
    loss, grads, new_a, new_g = M.model_step(ws, old_a, old_g, x, y, rho=rho)
    logits, acts = M.forward(ws, x)
    _, p = M.softmax_xent(logits, y)
    _, gf = M.backward(ws, acts, p, y)
    for l in range(n):
        want_a = ref.ea_gram_ref(old_a[l], acts[l], rho, float(BATCH))
        want_g = ref.ea_gram_ref(old_g[l], gf[l], rho, float(BATCH))
        np.testing.assert_allclose(new_a[l], want_a, rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(new_g[l], want_g, rtol=1e-3, atol=1e-4)
    assert float(loss) > 0.0


def test_eval_counts_correct(setup):
    ws, x, y = setup
    loss, correct = M.model_eval(ws, x, y)
    logits = ref.mlp_forward_ref(ws, x)
    want = (jnp.argmax(logits, 0) == jnp.argmax(y, 0)).sum()
    assert int(correct) == int(want)
    assert 0 <= int(correct) <= BATCH


def test_sgd_step_descends():
    key = jax.random.PRNGKey(3)
    ws = M.init_params(WIDTHS, key)
    x = jax.random.normal(jax.random.PRNGKey(4), (WIDTHS[0], BATCH), jnp.float32)
    labels = jax.random.randint(jax.random.PRNGKey(5), (BATCH,), 0, WIDTHS[-1])
    y = jax.nn.one_hot(labels, WIDTHS[-1], axis=0, dtype=jnp.float32)
    loss0, ws1 = M.sgd_step(ws, x, y, lr=0.05, weight_decay=0.0)
    # Same batch: a small step must reduce the loss.
    loss1, _ = M.sgd_step(ws1, x, y, lr=0.05, weight_decay=0.0)
    assert float(loss1) < float(loss0)


def _one_hot_labels(classes, batch):
    labels = np.arange(batch) % classes
    return jnp.asarray(np.eye(classes, dtype=np.float32)[:, labels])


def test_flat_step_fn_signature():
    widths, batch = [8, 6, 10], 4
    step, ins = M.make_step_fn(widths, batch, rho=0.95)
    n = len(widths) - 1
    assert len(ins) == 3 * n + 2
    args = [jnp.zeros(s.shape, s.dtype) for s in ins]
    args[-1] = _one_hot_labels(widths[-1], batch)
    # zero weights -> uniform softmax -> loss = log(C)
    out = step(*args)
    assert len(out) == 1 + 3 * n
    np.testing.assert_allclose(out[0], np.log(widths[-1]), rtol=1e-5)


def test_flat_eval_fn_signature():
    widths, batch = [8, 6, 10], 4
    ev, ins = M.make_eval_fn(widths, batch)
    args = [jnp.zeros(s.shape, s.dtype) for s in ins]
    args[-1] = _one_hot_labels(widths[-1], batch)
    loss, correct = ev(*args)
    np.testing.assert_allclose(loss, np.log(widths[-1]), rtol=1e-5)
