"""AOT pipeline: HLO-text emission + manifest integrity.

Uses a temp directory and the `tiny` config only (fast); the full artifact
set is exercised by `make artifacts` + the Rust integration tests.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.build_all(out, configs=["tiny"])
    return out


def test_manifest_lists_all_files(built):
    with open(os.path.join(built, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["version"] == 1
    rows = manifest["artifacts"]
    assert len(rows) >= 5  # 3 model entry points + 3 kernels
    for row in rows:
        path = os.path.join(built, row["file"])
        assert os.path.exists(path), row["file"]
        assert row["inputs"] and row["outputs"]
        for spec in row["inputs"] + row["outputs"]:
            assert spec["dtype"] == "float32"
            assert all(isinstance(d, int) for d in spec["shape"])


def test_hlo_text_is_parsable_hlo(built):
    # HLO text must contain an ENTRY computation and f32 shapes; and must NOT
    # be a serialized proto (the 0.5.1 interchange constraint).
    path = os.path.join(built, "mlp_step_tiny.hlo.txt")
    text = open(path).read()
    assert "ENTRY" in text
    assert "f32" in text
    assert text.lstrip().startswith("HloModule")


def test_step_artifact_signature_matches_model(built):
    with open(os.path.join(built, "manifest.json")) as f:
        rows = {r["name"]: r for r in json.load(f)["artifacts"]}
    row = rows["mlp_step_tiny"]
    widths = row["meta"]["widths"]
    batch = row["meta"]["batch"]
    n = len(widths) - 1
    assert len(row["inputs"]) == 3 * n + 2
    assert len(row["outputs"]) == 1 + 3 * n
    # loss is scalar
    assert row["outputs"][0]["shape"] == []
    # x/y shapes
    assert row["inputs"][-2]["shape"] == [widths[0], batch]
    assert row["inputs"][-1]["shape"] == [widths[-1], batch]


def test_lowered_step_matches_eager():
    """jit-lowered step output == eager python output (numerics preserved)."""
    widths, batch = [16, 8, 10], 4
    step, ins = M.make_step_fn(widths, batch, rho=0.95)
    key = jax.random.PRNGKey(0)
    args = []
    for s in ins:
        key, sub = jax.random.split(key)
        args.append(0.1 * jax.random.normal(sub, s.shape, s.dtype))
    eager = step(*args)
    jitted = jax.jit(step)(*args)
    for e, j in zip(eager, jitted):
        np.testing.assert_allclose(np.asarray(j), np.asarray(e), rtol=1e-4, atol=1e-5)


def test_hlo_text_roundtrip_stable(built):
    # Lowering the same fn twice produces identical text (determinism of the
    # AOT path — required for `make artifacts` no-op freshness checks).
    step, ins = M.make_step_fn([16, 8, 10], 4, rho=0.95)
    t1 = aot.to_hlo_text(jax.jit(step).lower(*ins))
    t2 = aot.to_hlo_text(jax.jit(step).lower(*ins))
    assert t1 == t2
