"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (including non-tile-aligned ones) and values; every
kernel must match its `ref.py` oracle to f32 tolerance.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ea_gram as eg
from compile.kernels import lowrank_apply as la
from compile.kernels import matmul as mk
from compile.kernels import ref
from compile.kernels import sketch as sk
from compile.kernels.common import cdiv, pad2, pick_block, round_up

DIM = st.integers(min_value=1, max_value=80)
SMALL = st.integers(min_value=1, max_value=24)
SEED = st.integers(min_value=0, max_value=2**31 - 1)


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


# ---------------------------------------------------------------------------
# common.py helpers
# ---------------------------------------------------------------------------


@given(a=st.integers(1, 10_000), b=st.integers(1, 512))
def test_cdiv_round_up(a, b):
    assert cdiv(a, b) == -(-a // b)
    r = round_up(a, b)
    assert r % b == 0 and r >= a and r - a < b


@given(r=DIM, c=DIM, br=st.sampled_from([8, 32, 128]), bc=st.sampled_from([8, 32, 128]))
@settings(max_examples=25, deadline=None)
def test_pad2_preserves_content(r, c, br, bc):
    rng = np.random.default_rng(0)
    x = rand(rng, r, c)
    p = pad2(x, br, bc)
    assert p.shape[0] % br == 0 and p.shape[1] % bc == 0
    np.testing.assert_array_equal(np.asarray(p[:r, :c]), np.asarray(x))
    assert float(jnp.abs(p).sum()) == pytest.approx(float(jnp.abs(x).sum()), rel=1e-6)


def test_pick_block_bounds():
    assert pick_block(1000) == 128
    assert pick_block(8) == 8
    assert pick_block(3) == 8
    assert pick_block(100) % 8 == 0 and pick_block(100) >= 100


# ---------------------------------------------------------------------------
# matmul / matmul_axpy
# ---------------------------------------------------------------------------


@given(m=DIM, k=DIM, n=DIM, seed=SEED)
@settings(max_examples=30, deadline=None)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, b = rand(rng, m, k), rand(rng, k, n)
    got = mk.matmul(a, b)
    np.testing.assert_allclose(got, ref.matmul_ref(a, b), rtol=2e-4, atol=2e-4)


@given(m=DIM, k=DIM, n=DIM, beta=st.floats(-3, 3), seed=SEED)
@settings(max_examples=20, deadline=None)
def test_matmul_axpy_matches_ref(m, k, n, beta, seed):
    rng = np.random.default_rng(seed)
    a, b, c0 = rand(rng, m, k), rand(rng, k, n), rand(rng, m, n)
    got = mk.matmul_axpy(a, b, c0, beta)
    np.testing.assert_allclose(got, a @ b + beta * c0, rtol=2e-4, atol=2e-4)


def test_matmul_large_multi_tile():
    rng = np.random.default_rng(7)
    a, b = rand(rng, 300, 260), rand(rng, 260, 140)
    np.testing.assert_allclose(mk.matmul(a, b), a @ b, rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# ea_gram
# ---------------------------------------------------------------------------


@given(d=DIM, n=SMALL, rho=st.floats(0.0, 0.999), seed=SEED)
@settings(max_examples=30, deadline=None)
def test_ea_gram_matches_ref(d, n, rho, seed):
    rng = np.random.default_rng(seed)
    old = rand(rng, d, d)
    m = rand(rng, d, n)
    got = eg.ea_gram(old, m, rho=rho, denom=float(n))
    want = ref.ea_gram_ref(old, m, rho, float(n))
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


def test_ea_gram_identity_fixpoint():
    # With M = 0, the update is pure decay of OLD.
    old = jnp.eye(33, dtype=jnp.float32)
    m = jnp.zeros((33, 5), jnp.float32)
    got = eg.ea_gram(old, m, rho=0.9, denom=5.0)
    np.testing.assert_allclose(got, 0.9 * np.eye(33), rtol=1e-6, atol=1e-6)


def test_ea_gram_output_symmetric():
    rng = np.random.default_rng(3)
    old_half = rand(rng, 50, 50)
    old = old_half + old_half.T
    m = rand(rng, 50, 12)
    got = np.asarray(eg.ea_gram(old, m, rho=0.95, denom=12.0))
    np.testing.assert_allclose(got, got.T, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# lowrank_apply (eq. 13)
# ---------------------------------------------------------------------------


@given(d=st.integers(4, 60), r=st.integers(1, 12), c=SMALL, lam=st.floats(0.05, 2.0), seed=SEED)
@settings(max_examples=25, deadline=None)
def test_lowrank_apply_matches_ref(d, r, c, lam, seed):
    r = min(r, d)
    rng = np.random.default_rng(seed)
    u = jnp.asarray(np.linalg.qr(rng.normal(size=(d, r)))[0], jnp.float32)
    dv = jnp.asarray(np.abs(rng.normal(size=r)) + 0.1, jnp.float32)
    v = rand(rng, d, c)
    got = la.lowrank_apply(u, dv, lam, v)
    want = ref.lowrank_apply_ref(u, dv, lam, v)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_lowrank_apply_is_true_inverse():
    # (U D U^T + lam I) @ lowrank_apply(...) V == V for full-rank U.
    rng = np.random.default_rng(11)
    d, lam = 24, 0.4
    u = jnp.asarray(np.linalg.qr(rng.normal(size=(d, d)))[0], jnp.float32)
    dv = jnp.asarray(np.abs(rng.normal(size=d)) + 0.5, jnp.float32)
    v = rand(rng, d, 4)
    x = la.lowrank_apply(u, dv, lam, v)
    full = u @ jnp.diag(dv) @ u.T + lam * jnp.eye(d)
    np.testing.assert_allclose(full @ x, v, rtol=5e-3, atol=5e-3)


def test_lowrank_precondition_shapes():
    rng = np.random.default_rng(5)
    do, di, r = 20, 30, 6
    ug = jnp.asarray(np.linalg.qr(rng.normal(size=(do, r)))[0], jnp.float32)
    ua = jnp.asarray(np.linalg.qr(rng.normal(size=(di, r)))[0], jnp.float32)
    dg = jnp.asarray(np.abs(rng.normal(size=r)) + 0.1, jnp.float32)
    da = jnp.asarray(np.abs(rng.normal(size=r)) + 0.1, jnp.float32)
    grad = rand(rng, do, di)
    out = la.lowrank_precondition(ug, dg, ua, da, 0.3, grad)
    assert out.shape == (do, di)
    want = ref.lowrank_apply_ref(ug, dg, 0.3, grad)
    want = ref.lowrank_apply_ref(ua, da, 0.3, want.T).T
    np.testing.assert_allclose(out, want, rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# sketch / range finder
# ---------------------------------------------------------------------------


@given(d=DIM, s=SMALL, seed=SEED)
@settings(max_examples=20, deadline=None)
def test_sketch_matches_ref(d, s, seed):
    rng = np.random.default_rng(seed)
    x, om = rand(rng, d, d), rand(rng, d, s)
    np.testing.assert_allclose(sk.sketch(x, om), ref.sketch_ref(x, om), rtol=3e-4, atol=3e-4)


def test_range_sketch_orthonormal_and_captures_range():
    rng = np.random.default_rng(13)
    g = rng.normal(size=(60, 6))
    x = jnp.asarray(g @ g.T, jnp.float32)  # rank 6 PSD
    om = rand(rng, 60, 10)
    q = sk.range_sketch(x, om, n_pwr_it=2)
    assert q.shape == (60, 10)
    np.testing.assert_allclose(q.T @ q, np.eye(10), atol=1e-4)
    resid = x - q @ (q.T @ x)
    assert float(jnp.linalg.norm(resid)) < 1e-2 * float(jnp.linalg.norm(x))


def test_srevd_core_eigenvalues_match():
    rng = np.random.default_rng(17)
    g = rng.normal(size=(50, 5))
    x = jnp.asarray(g @ g.T, jnp.float32)
    om = rand(rng, 50, 9)
    q, c = sk.srevd_core(x, om, n_pwr_it=2)
    lam_core = np.sort(np.linalg.eigvalsh(np.asarray(c)))[::-1]
    lam_true = np.sort(np.linalg.eigvalsh(np.asarray(x)))[::-1]
    np.testing.assert_allclose(lam_core[:5], lam_true[:5], rtol=1e-3)
