"""Make `import compile...` work when pytest runs from the repo root."""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
