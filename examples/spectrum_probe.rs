//! Fig. 1 reproduction driver — K-factor eigen-spectrum over training.
//!
//! Trains with exact K-FAC and dumps the EA K-factor spectra of two layers
//! on the paper's cadence, then prints, per snapshot, how many modes the
//! spectrum needs to decay 1.5 orders of magnitude (the paper: ~200 modes
//! at equilibrium, independent of layer width).
//!
//! Run: `cargo run --release --example spectrum_probe [-- --steps 400]`

use rkfac::coordinator::config::{DataChoice, EngineChoice, ModelChoice, TrainConfig};
use rkfac::coordinator::spectrum::{run_probe, spectrum_csv, SpectrumConfig};
use rkfac::rnla::errors;
use rkfac::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cfg = TrainConfig {
        solver: "kfac".into(),
        epochs: 4,
        batch: 128,
        seed: 7,
        model: ModelChoice::Mlp { widths: vec![768, 512, 256, 10] },
        data: DataChoice::Synthetic { n_train: 4096, n_test: 512, height: 16, width: 16, channels: 3 },
        engine: EngineChoice::Native,
        targets: vec![],
        augment: false,
        out_dir: "results/fig1".into(),
        sched_width: 0,
        ..Default::default()
    };
    let probe = SpectrumConfig {
        early_every: 10,
        early_until: 60,
        late_every: 30,
        blocks: vec![0, 1], // the 768- and 512-wide blocks
        steps: args.get_usize("steps", 240),
        t_ku: args.get_usize("t_ku", 3),
        t_ki: args.get_usize("t_ki", 30),
    };
    let out = "results/fig1/spectrum.csv";
    let mut log = spectrum_csv(out)?;
    println!("== Fig.1 probe: eigen-spectrum of EA K-factors during training ==");
    let snaps = run_probe(&cfg, &probe, Some(&mut log))?;
    println!("{:>6} {:>6} {:>3} {:>12} {:>18} {:>22}", "step", "block", "fac", "lambda_max", "modes>1%max", "modes_to_1.5_orders");
    for s in &snaps {
        println!(
            "{:>6} {:>6} {:>3} {:>12.4e} {:>18} {:>22}",
            s.step,
            s.block,
            s.factor,
            s.lambda.first().copied().unwrap_or(0.0),
            errors::modes_above(&s.lambda, 0.01),
            s.modes_to_15_orders().map(|m| m.to_string()).unwrap_or_else(|| "—".into()),
        );
    }
    println!("\nfull spectra -> {out}");
    println!("paper shape to observe: early snapshots flat (identity init),");
    println!("later snapshots decay ≥1.5 orders within a few hundred modes.");
    Ok(())
}
