//! Quickstart — the end-to-end driver, on the Experiment/Session API.
//!
//! Builds a layered [`ExperimentSpec`] (inline TOML < builder calls — the
//! same precedence chain `rkfac train --set key=value` extends from the
//! CLI) and wires a [`Session`] from it (run hooks are demoed in
//! `vgg_cifar` and the `rkfac train` CLI).
//! Trains an MLP on synthetic CIFAR-like data with RS-KFAC through
//! the **full three-layer stack**: the fused fwd/bwd + EA-gram compute
//! runs in the AOT-compiled JAX/Pallas artifact via PJRT (L2/L1), the
//! randomized K-FAC optimizer and the training loop run in Rust (L3).
//! Falls back to the native engine — one higher-precedence builder
//! assignment on the same chain — if `artifacts/` is missing.
//!
//! Run: `cargo run --release --example quickstart`
//! The loss curve is printed per epoch and written to results/quickstart/.
//!
//! Checkpoint + resume: the same spec drives crash-safe full-state
//! checkpointing — `rkfac train --config <toml> --checkpoint-every 1`
//! writes `ckpt_<solver>_<seed>_e<epoch>.bin` (network params, solver EA
//! factors/counters, RNG streams) after each epoch, and an interrupted
//! run continues **bitwise** with
//! `rkfac train --config <toml> --resume results/ckpt_rs-kfac_1_e0003.bin`
//! (or `spec.session().resume(path)` from code).
//!
//! Vocab-scale output heads: add a `[factored]` section (or pick the
//! `kfac+woodbury` solver spec) to route wide G blocks through the
//! Woodbury retained-column path instead of the o×o eigen path — see
//! docs/factored.md and `cargo run --release --example wide_head`
//! (`rkfac train --config configs/wide_head.toml` trains a 512→50k head).
//!
//! [`ExperimentSpec`]: rkfac::coordinator::ExperimentSpec
//! [`Session`]: rkfac::coordinator::Session

use rkfac::coordinator::experiment::ExperimentBuilder;

/// The shared layer chain: durable experiment shape in TOML, per-invocation
/// knobs as builder calls.
fn base_experiment() -> anyhow::Result<ExperimentBuilder> {
    Ok(ExperimentBuilder::new()
        .toml_str(
            r#"
[model]
kind = "mlp"
widths = [768, 256, 256, 10]

[data]
kind = "synthetic"
n_train = 2560
n_test = 512
height = 16        # 16x16x3 -> 768 inputs
width = 16

[engine]
kind = "pjrt"
config = "quick"

[train]
targets = [0.70, 0.75, 0.80]
out_dir = "results/quickstart"
"#,
        )?
        .solver("kfac+rsvd") // canonical spec for the paper's RS-KFAC
        .epochs(5)
        .batch(128)
        .seed(1))
}

fn main() -> anyhow::Result<()> {
    let spec = base_experiment()?.build()?;
    println!("== rkfac quickstart: RS-KFAC on synthetic CIFAR (16x16x3 -> 10 classes) ==");
    // Any failure of the PJRT attempt (typically the missing/stubbed
    // artifact engine) falls back to native; the CSV is written once,
    // after whichever run sticks.
    let (spec, result) = match spec.session().run() {
        Ok(r) => {
            println!("engine: PJRT (mlp_step_quick artifact — JAX/Pallas compute)");
            (spec, r)
        }
        Err(e) => {
            eprintln!("[quickstart] PJRT engine unavailable ({e:#}); falling back to native nn");
            // The fallback is just a higher-precedence assignment on the
            // same layer chain — the TOML engine section loses to it.
            let native = base_experiment()?.set("engine.kind", "native").build()?;
            let r = native.session().run()?;
            (native, r)
        }
    };

    println!("\nloss curve (per epoch):");
    for r in &result.records {
        let bar_len = ((r.test_acc * 40.0) as usize).min(40);
        println!(
            "  epoch {:>2}  wall {:>7.1}s  train {:.4}  test {:.4}  acc {:>5.1}%  |{}{}|",
            r.epoch,
            r.wall_s,
            r.train_loss,
            r.test_loss,
            r.test_acc * 100.0,
            "#".repeat(bar_len),
            " ".repeat(40 - bar_len),
        );
    }
    for &t in &spec.cfg().targets {
        match result.time_to_acc(t) {
            Some(s) => println!("time to {:>4.1}%: {s:.1}s", t * 100.0),
            None => {
                println!("time to {:>4.1}%: not reached in {} epochs", t * 100.0, spec.cfg().epochs)
            }
        }
    }
    let csv = format!("{}/run_{}_{}.csv", spec.cfg().out_dir, result.solver, result.seed);
    result.write_csv(&csv)?;
    println!("series -> {csv}");

    let last = result.records.last().expect("no epochs ran");
    anyhow::ensure!(last.test_loss.is_finite(), "diverged");
    anyhow::ensure!(
        last.test_acc > 0.4,
        "quickstart under-trained: acc {:.3} (expected > 0.4)",
        last.test_acc
    );
    println!("\nquickstart OK — all three layers (rust coordinator / JAX model / Pallas kernels) composed.");
    Ok(())
}
