//! Quickstart — the end-to-end driver (DESIGN.md "end-to-end validation").
//!
//! Trains an MLP on synthetic CIFAR-like data with RS-KFAC through the
//! **full three-layer stack**: the fused fwd/bwd + EA-gram compute runs in
//! the AOT-compiled JAX/Pallas artifact via PJRT (L2/L1), the randomized
//! K-FAC optimizer and the training loop run in Rust (L3). Falls back to
//! the native engine with a warning if `artifacts/` is missing.
//!
//! Run: `cargo run --release --example quickstart`
//! The loss curve is printed per epoch and written to results/quickstart/.

use rkfac::coordinator::config::{DataChoice, EngineChoice, ModelChoice, TrainConfig};
use rkfac::coordinator::trainer;

fn main() -> anyhow::Result<()> {
    let mut cfg = TrainConfig {
        solver: "rs-kfac".into(),
        epochs: 5,
        batch: 128,
        seed: 1,
        model: ModelChoice::Mlp { widths: vec![768, 256, 256, 10] },
        data: DataChoice::Synthetic { n_train: 2560, n_test: 512, height: 16, width: 16, channels: 3 },
        engine: EngineChoice::Pjrt { config: "quick".into() },
        targets: vec![0.70, 0.75, 0.80],
        augment: false,
        out_dir: "results/quickstart".into(),
        sched_width: 0,
        pipeline: rkfac::pipeline::PipelineConfig::default(),
    };

    println!("== rkfac quickstart: RS-KFAC on synthetic CIFAR (16x16x3 -> 10 classes) ==");
    let result = match trainer::run(&cfg) {
        Ok(r) => {
            println!("engine: PJRT (mlp_step_quick artifact — JAX/Pallas compute)");
            r
        }
        Err(e) => {
            eprintln!("[quickstart] PJRT engine unavailable ({e:#}); falling back to native nn");
            cfg.engine = EngineChoice::Native;
            trainer::run(&cfg)?
        }
    };

    println!("\nloss curve (per epoch):");
    for r in &result.records {
        let bar_len = ((r.test_acc * 40.0) as usize).min(40);
        println!(
            "  epoch {:>2}  wall {:>7.1}s  train {:.4}  test {:.4}  acc {:>5.1}%  |{}{}|",
            r.epoch,
            r.wall_s,
            r.train_loss,
            r.test_loss,
            r.test_acc * 100.0,
            "#".repeat(bar_len),
            " ".repeat(40 - bar_len),
        );
    }
    for &t in &cfg.targets {
        match result.time_to_acc(t) {
            Some(s) => println!("time to {:>4.1}%: {s:.1}s", t * 100.0),
            None => println!("time to {:>4.1}%: not reached in {} epochs", t * 100.0, cfg.epochs),
        }
    }
    let csv = format!("{}/quickstart_{}.csv", cfg.out_dir, result.seed);
    result.write_csv(&csv)?;
    println!("series -> {csv}");

    let last = result.records.last().expect("no epochs ran");
    anyhow::ensure!(last.test_loss.is_finite(), "diverged");
    anyhow::ensure!(
        last.test_acc > 0.4,
        "quickstart under-trained: acc {:.3} (expected > 0.4)",
        last.test_acc
    );
    println!("\nquickstart OK — all three layers (rust coordinator / JAX model / Pallas kernels) composed.");
    Ok(())
}
