//! Internal perf probe used during the optimization pass (EXPERIMENTS.md §Perf).
//!
//! Also reports the pipeline rank controller's per-block adaptive rank at a
//! configurable error target (`--target 0.03`), so bench output stays
//! comparable across PRs now that ranks are chosen per layer — and, since
//! the trait redesign, the sketch parameters the strategy's `tune` hook
//! picks for that rank/target plus its `DecompMeta` cost estimate.
use rkfac::linalg::{qr, svd, Pcg64};
use rkfac::pipeline::RankController;
use rkfac::rnla::{decomposition, rsvd, Decomposition, SketchConfig};
use rkfac::util::benchkit::{bench, print_table};
use rkfac::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let target = args.get_f64("target", 0.03);
    let mut rng = Pcg64::new(1);
    let tall = rng.gaussian_matrix(768, 230);
    let psd = {
        let g = rng.gaussian_matrix(768, 192);
        let mut s = rkfac::linalg::gemm::syrk(&g);
        s.add_diag(0.05);
        s
    };
    let mut out = Vec::new();
    out.push(bench("thin_qr_768x230", 1, 3, || {
        std::hint::black_box(qr::thin_qr(&tall));
    }));
    out.push(bench("jacobi_svd_768x230", 1, 3, || {
        std::hint::black_box(svd::jacobi_svd(&tall));
    }));
    let mut r = Pcg64::new(2);
    out.push(bench("rsvd_768_r220", 1, 3, || {
        std::hint::black_box(rsvd(&psd, &SketchConfig::new(220, 10, 4), &mut r));
    }));
    print_table("perf probe", &out);

    // Adaptive per-block rank at the requested target: iterate the
    // controller on each block's observed RSVD spectrum until it settles,
    // exactly as the pipeline does across refresh rounds.
    println!("\n== adaptive rank per block (target rel err {target}) ==");
    let blocks = [("ea_decay_0.96", 768usize, 0.96f64), ("ea_decay_0.90", 512, 0.90)];
    for (name, d, decay) in blocks {
        let x = {
            let q = qr::orthonormalize(&rng.gaussian_matrix(d, d));
            let lam: Vec<f64> = (0..d).map(|i| decay.powi(i as i32).max(1e-10)).collect();
            let mut qd = q.clone();
            rkfac::linalg::gemm::scale_cols(&mut qd, &lam);
            rkfac::linalg::gemm::matmul_nt(&qd, &q)
        };
        let mut ctl = RankController::new(220.min(d), d, target, 8, 1.5, 0.95, 0);
        let mut srng = Pcg64::new(7);
        for _ in 0..12 {
            let f = rsvd(&x, &SketchConfig::new(ctl.rank, 10, 2), &mut srng);
            ctl.observe(&f.sigma);
        }
        // What the strategy's controller-feedback hook would run with at
        // the settled rank (the pipeline's `adaptive_sketch` path).
        let strategy = decomposition::Rsvd;
        let tuned = strategy.tune(&SketchConfig::new(ctl.rank, 10, 4), ctl.rank, target);
        let meta = strategy.meta(d, &tuned);
        println!(
            "{name:<16} d={d:<5} chosen rank = {:<5} ({} observations)  tuned sketch: r_l={} \
             n_pwr={}  ~{:.2e} flops/decomp",
            ctl.rank, ctl.observations, tuned.oversample, tuned.n_power_iter, meta.flops
        );
    }

    // Epoch-indexed per-strategy schedule ([schedules] TOML section): what
    // the session installs through the same tune hook at each epoch
    // boundary — here, RSVD relaxing its power iterations late in the run.
    println!(
        "\n== [schedules] epoch-indexed sketch for rsvd (n_pwr 4 -> 2 @ e30; tune floors \
         r_l at (r+9)/10) =="
    );
    let mut set = rkfac::optim::StrategySchedules::default();
    set.insert(
        "rsvd",
        rkfac::optim::StrategySchedule {
            oversample: Some(rkfac::optim::StepSchedule::new(10.0, vec![(22, 1.0), (30, 1.0)])),
            power_iter: Some(rkfac::optim::StepSchedule::new(4.0, vec![(30, -2.0)])),
            // Tight default ε: tune keeps the scheduled power iters instead
            // of relaxing them, so the epoch steps show through.
            target_rel_err: None,
        },
    );
    let sched = rkfac::optim::KfacSchedules::paper();
    for epoch in [0usize, 22, 30, 45] {
        let s = set.sketch_for(&decomposition::Rsvd, &sched, epoch).unwrap();
        println!(
            "epoch {epoch:>3}: rank={:<4} r_l={:<3} n_pwr={}",
            s.rank, s.oversample, s.n_power_iter
        );
    }
}
