//! Internal perf probe used during the optimization pass (EXPERIMENTS.md §Perf).
use rkfac::linalg::{qr, svd, Pcg64};
use rkfac::rnla::{rsvd, SketchConfig};
use rkfac::util::benchkit::{bench, print_table};
fn main() {
    let mut rng = Pcg64::new(1);
    let tall = rng.gaussian_matrix(768, 230);
    let psd = {
        let g = rng.gaussian_matrix(768, 192);
        let mut s = rkfac::linalg::gemm::syrk(&g);
        s.add_diag(0.05);
        s
    };
    let mut out = Vec::new();
    out.push(bench("thin_qr_768x230", 1, 3, || {
        std::hint::black_box(qr::thin_qr(&tall));
    }));
    out.push(bench("jacobi_svd_768x230", 1, 3, || {
        std::hint::black_box(svd::jacobi_svd(&tall));
    }));
    let mut r = Pcg64::new(2);
    out.push(bench("rsvd_768_r220", 1, 3, || {
        std::hint::black_box(rsvd(&psd, &SketchConfig::new(220, 10, 4), &mut r));
    }));
    print_table("perf probe", &out);
}
