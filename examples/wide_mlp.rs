//! Wide-MLP width-scaling demo — the regime the paper targets.
//!
//! The complexity claim (§4.4): K-FAC's decomposition cost is O(d³) in
//! layer width, Randomized K-FACs' is O(d²(r+r_l)). This example trains a
//! wide-hidden-layer MLP at several widths and reports the *measured
//! decomposition seconds* per solver, showing the gap widen with width —
//! the same effect Table 1's t_epoch column shows at VGG16 scale.
//!
//! Run: `cargo run --release --example wide_mlp [-- --widths 256,512,1024]`

use rkfac::coordinator::config::{DataChoice, EngineChoice, ModelChoice, TrainConfig};
use rkfac::coordinator::trainer;
use rkfac::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let widths: Vec<usize> = args
        .get_or("widths", "256,512,1024")
        .split(',')
        .map(|w| w.parse().expect("bad width"))
        .collect();
    let epochs = args.get_usize("epochs", 1);

    println!("== width scaling: decomposition cost, K-FAC vs RS-KFAC vs SRE-KFAC ==");
    println!(
        "{:>6} {:>12} {:>12} {:>12}   {:>8}",
        "width", "kfac_dec_s", "rs_dec_s", "sre_dec_s", "speedup"
    );
    for &w in &widths {
        let mut decs = Vec::new();
        for solver in ["kfac", "rs-kfac", "sre-kfac"] {
            let cfg = TrainConfig {
                solver: solver.into(),
                epochs,
                batch: 128,
                seed: 3,
                model: ModelChoice::Mlp { widths: vec![768, w, 10] },
                data: DataChoice::Synthetic { n_train: 1280, n_test: 256, height: 16, width: 16, channels: 3 },
                engine: EngineChoice::Native,
                targets: vec![],
                augment: false,
                out_dir: "results/wide_mlp".into(),
                sched_width: w,
                ..Default::default()
            };
            let r = trainer::run(&cfg)?;
            let dec = r.records.last().map(|rec| rec.decomp_s).unwrap_or(0.0);
            decs.push(dec);
            r.write_csv(format!("results/wide_mlp/w{w}_{solver}.csv"))?;
        }
        let speedup = decs[0] / decs[1].max(1e-9);
        println!(
            "{:>6} {:>12.3} {:>12.3} {:>12.3}   {:>7.2}x",
            w, decs[0], decs[1], decs[2], speedup
        );
    }
    println!("\nexpected shape: kfac column grows ~cubically with width, the");
    println!("randomized columns ~quadratically; the speedup factor widens.");
    Ok(())
}
