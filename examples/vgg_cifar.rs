//! VGG16_bn on (synthetic or real) CIFAR-10 — the paper's §5 workload.
//!
//! Uses the channel-scaled VGG16_bn (13 conv + 2 FC Kronecker blocks,
//! BatchNorm everywhere, dropout before the classifier — the paper's
//! modified architecture) on 32×32×3 inputs. If real CIFAR-10 binaries are
//! present under `data/cifar-10-batches-bin`, they are used; otherwise the
//! synthetic generator stands in (see DESIGN.md §Substitutions).
//!
//! Run: `cargo run --release --example vgg_cifar [-- --solver rs-kfac --epochs 2 --scale-div 16]`
//! (scale_div 16 keeps a 1-core run to minutes; 1 = the real 15M-param net)

use rkfac::coordinator::config::{DataChoice, EngineChoice, ModelChoice, TrainConfig};
use rkfac::coordinator::trainer;
use rkfac::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cifar_root = "data/cifar-10-batches-bin";
    let data = if rkfac::data::cifar::is_available(cifar_root) {
        println!("using real CIFAR-10 from {cifar_root}");
        DataChoice::Cifar {
            root: cifar_root.into(),
            n_train: args.get_usize("n-train", 4096),
            n_test: args.get_usize("n-test", 1024),
        }
    } else {
        println!("real CIFAR-10 not found under {cifar_root}; using the synthetic stand-in");
        DataChoice::Synthetic {
            n_train: args.get_usize("n-train", 1024),
            n_test: args.get_usize("n-test", 256),
            height: 32,
            width: 32,
            channels: 3,
        }
    };
    let cfg = TrainConfig {
        solver: args.get_or("solver", "rs-kfac").to_string(),
        epochs: args.get_usize("epochs", 2),
        batch: args.get_usize("batch", 64),
        seed: args.get_usize("seed", 5) as u64,
        model: ModelChoice::Vgg16Bn { scale_div: args.get_usize("scale-div", 16) },
        data,
        engine: EngineChoice::Native,
        targets: vec![0.3, 0.5],
        augment: args.has("augment"),
        out_dir: "results/vgg".into(),
        sched_width: 0,
        pipeline: rkfac::pipeline::PipelineConfig::default(),
    };
    println!(
        "== VGG16_bn/{} with {} ({} epochs, batch {}) ==",
        args.get_usize("scale-div", 16),
        cfg.solver,
        cfg.epochs,
        cfg.batch
    );
    let result = trainer::run(&cfg)?;
    for r in &result.records {
        println!(
            "epoch {:>2}  wall {:>8.1}s  train {:.4}  test {:.4}  acc {:>5.1}%  decomp {:>6.1}s",
            r.epoch,
            r.wall_s,
            r.train_loss,
            r.test_loss,
            r.test_acc * 100.0,
            r.decomp_s
        );
    }
    result.write_csv(format!("results/vgg/{}_{}.csv", result.solver, result.seed))?;
    let last = result.records.last().expect("no epochs");
    anyhow::ensure!(last.test_loss.is_finite(), "diverged");
    println!("done; best acc {:.1}%", result.best_acc() * 100.0);
    Ok(())
}
