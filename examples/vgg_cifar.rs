//! VGG16_bn on (synthetic or real) CIFAR-10 — the paper's §5 workload, on
//! the Experiment API.
//!
//! Uses the channel-scaled VGG16_bn (13 conv + 2 FC Kronecker blocks,
//! BatchNorm everywhere, dropout before the classifier — the paper's
//! modified architecture) on 32×32×3 inputs. If real CIFAR-10 binaries are
//! present under `data/cifar-10-batches-bin`, they are used; otherwise the
//! synthetic generator stands in (see DESIGN.md §Substitutions). The
//! config is assembled as one layered spec: inline TOML for the durable
//! shape, CLI flags lowered onto `--set`-style overrides on top — pass
//! `--set key=value` directly to reach *any* config key (e.g.
//! `--set pipeline.enabled=true`).
//!
//! Run: `cargo run --release --example vgg_cifar [-- --solver rs-kfac --epochs 2 --scale-div 16 --set pipeline.enabled=true]`
//! (scale_div 16 keeps a 1-core run to minutes; 1 = the real 15M-param net)

use rkfac::coordinator::experiment::ExperimentBuilder;
use rkfac::coordinator::hooks::CsvMetricsHook;
use rkfac::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cifar_root = "data/cifar-10-batches-bin";
    let mut b = ExperimentBuilder::new().toml_str(
        r#"
[model]
kind = "vgg16_bn"
scale_div = 16     # 1-core friendly; 1 = the real 15M-param net

[data]
kind = "synthetic"
height = 32
width = 32
n_train = 1024
n_test = 256

[train]
solver = "rs-kfac"
epochs = 2
batch = 64
seed = 5
targets = [0.3, 0.5]
out_dir = "results/vgg"
"#,
    )?;
    if rkfac::data::cifar::is_available(cifar_root) {
        println!("using real CIFAR-10 from {cifar_root}");
        b = b
            .set("data.kind", "cifar")
            .set("data.root", cifar_root)
            .set("data.n_train", "4096")
            .set("data.n_test", "1024");
    } else {
        println!("real CIFAR-10 not found under {cifar_root}; using the synthetic stand-in");
    }
    if args.has("augment") {
        b = b.override_set("train.augment=true")?;
    }
    // Every convenience flag lowers onto the same CLI override layer as
    // raw --set (which reaches any key — pipeline, schedules, registry,
    // …), so the later of `--scale-div 4` / `--set model.scale_div=8`
    // wins regardless of which spelling the user mixed.
    let spec = b
        .cli_args(
            &args,
            &[
                ("solver", "train.solver"),
                ("epochs", "train.epochs"),
                ("batch", "train.batch"),
                ("seed", "train.seed"),
                ("scale-div", "model.scale_div"),
                ("n-train", "data.n_train"),
                ("n-test", "data.n_test"),
            ],
        )?
        .build()?;

    let scale_div = match &spec.cfg().model {
        rkfac::coordinator::ModelChoice::Vgg16Bn { scale_div } => *scale_div,
        other => anyhow::bail!("vgg_cifar expects a vgg16_bn model, got {other:?}"),
    };
    println!(
        "== VGG16_bn/{} with {} ({} epochs, batch {}) ==",
        scale_div,
        spec.cfg().solver,
        spec.cfg().epochs,
        spec.cfg().batch
    );
    let mut session = spec.session();
    session.add_hook(Box::new(CsvMetricsHook::new(spec.cfg().out_dir.clone())));
    let result = session.run()?;
    for r in &result.records {
        println!(
            "epoch {:>2}  wall {:>8.1}s  train {:.4}  test {:.4}  acc {:>5.1}%  decomp {:>6.1}s",
            r.epoch,
            r.wall_s,
            r.train_loss,
            r.test_loss,
            r.test_acc * 100.0,
            r.decomp_s
        );
    }
    let last = result.records.last().expect("no epochs");
    anyhow::ensure!(last.test_loss.is_finite(), "diverged");
    println!("done; best acc {:.1}%", result.best_acc() * 100.0);
    Ok(())
}
