//! Vocab-scale output head via the factored (Woodbury) G-side path.
//!
//! A classifier head o ≥ tens-of-thousands wide is where dense K-FAC
//! stops being runnable at all: the G-side gram alone is o² doubles
//! (50 000² ≈ 20 GB) before the O(o³) eigendecomposition. The factored
//! policy (`[factored] mode = "all"`, see docs/factored.md) keeps the
//! EA recursion as at most `max_cols` retained gradient columns and
//! solves through the Woodbury identity — O(o·k²) time, O(o·k) memory —
//! so the head width only enters linearly.
//!
//! This example trains one-epoch synthetic runs at several head widths
//! and reports wall / decomposition seconds. Run:
//!
//!   cargo run --release --example wide_head [-- --heads 5000,20000 --epochs 1]
//!
//! (`--heads 50000` reproduces the configs/wide_head.toml workload.)

use rkfac::coordinator::config::{DataChoice, EngineChoice, ModelChoice, TrainConfig};
use rkfac::coordinator::{FactoredConfig, Session};
use rkfac::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let heads: Vec<usize> = args
        .get_or("heads", "5000,20000")
        .split(',')
        .map(|w| w.parse().expect("bad head width"))
        .collect();
    let epochs = args.get_usize("epochs", 1);

    println!("== factored (Woodbury) G-side: 512 → o classifier heads ==");
    println!("{:>8} {:>10} {:>12} {:>12}", "head", "wall_s", "decomp_s", "train_loss");
    for &o in &heads {
        let cfg = TrainConfig {
            solver: "kfac".into(),
            epochs,
            batch: 32,
            seed: 1,
            model: ModelChoice::Mlp { widths: vec![512, o] },
            data: DataChoice::Synthetic {
                n_train: 256,
                n_test: 64,
                height: 8,
                width: 8,
                channels: 8,
            },
            engine: EngineChoice::Native,
            targets: vec![],
            augment: false,
            out_dir: "results/wide_head".into(),
            sched_width: 512,
            factored: FactoredConfig { mode: "all".into(), ..FactoredConfig::default() },
            ..Default::default()
        };
        let r = Session::new(cfg).run()?;
        let last = r.records.last().expect("at least one epoch");
        println!(
            "{:>8} {:>10.2} {:>12.2} {:>12.4}",
            o, last.wall_s, last.decomp_s, last.train_loss
        );
    }
    println!();
    println!(
        "note: a dense G block at the largest head would be o² doubles before the O(o³) \
         eigendecomposition — the factored path never allocates it (obs counter \
         kfac.dense_g_alloc stays at zero for routed blocks)."
    );
    Ok(())
}
